//! Detector post-processing: anchor heads → region proposals.
//!
//! The AOT detector emits per-anchor (location confidence, class
//! probabilities, energy). The coordinator forms regions as connected
//! components (4-connectivity) of anchors above θ_loc, then assigns each
//! region the energy-weighted class distribution of its member anchors —
//! the "two-stage" behaviour of the FasterRCNN stand-in.

use crate::metrics::f1::PredBox;
use crate::sim::video::scene::GtBox;

/// Per-anchor head outputs for one frame.
pub struct FrameHeads<'a> {
    pub loc_conf: &'a [f32],
    /// Row-major `[A, K]`.
    pub cls_prob: &'a [f32],
    pub energy: &'a [f32],
    pub grid: usize,
    pub num_classes: usize,
}

/// Form region proposals from one frame's head outputs.
pub fn regions_from_heads(h: &FrameHeads<'_>, theta_loc: f64) -> Vec<PredBox> {
    let g = h.grid;
    let a = g * g;
    assert_eq!(h.loc_conf.len(), a);
    assert_eq!(h.energy.len(), a);
    assert_eq!(h.cls_prob.len(), a * h.num_classes);

    let mut visited = vec![false; a];
    let mut out = Vec::new();
    for start in 0..a {
        if visited[start] || (h.loc_conf[start] as f64) < theta_loc {
            continue;
        }
        // BFS over location-confident neighbours.
        let mut stack = vec![start];
        visited[start] = true;
        let mut cells = Vec::new();
        while let Some(c) = stack.pop() {
            cells.push(c);
            let (x, y) = (c % g, c / g);
            let mut push = |nc: usize| {
                if !visited[nc] && (h.loc_conf[nc] as f64) >= theta_loc {
                    visited[nc] = true;
                    stack.push(nc);
                }
            };
            if x > 0 {
                push(c - 1);
            }
            if x + 1 < g {
                push(c + 1);
            }
            if y > 0 {
                push(c - g);
            }
            if y + 1 < g {
                push(c + g);
            }
        }
        // Dense scenes merge neighbouring objects into one connected
        // component; split it by the per-anchor argmax class (a real
        // two-stage detector separates proposals per class before NMS).
        // Each object's cells share one appearance, so they agree on an
        // argmax; neighbouring objects usually disagree.
        for part in split_by_class(&cells, h) {
            out.push(region_from_cells(&part, h));
        }
    }
    out
}

/// Split a connected component into contiguous same-argmax-class groups,
/// then absorb singleton fragments (per-cell noise flips) into an adjacent
/// group.
fn split_by_class(cells: &[usize], h: &FrameHeads<'_>) -> Vec<Vec<usize>> {
    if cells.len() <= 1 {
        return vec![cells.to_vec()];
    }
    let g = h.grid;
    let k = h.num_classes;
    let in_comp: std::collections::BTreeSet<usize> = cells.iter().copied().collect();
    let argmax = |c: usize| -> usize {
        let row = &h.cls_prob[c * k..(c + 1) * k];
        let mut best = (0usize, f32::MIN);
        for (j, &p) in row.iter().enumerate() {
            if p > best.1 {
                best = (j, p);
            }
        }
        best.0
    };
    let neighbours = |c: usize| {
        let (x, y) = (c % g, c / g);
        let mut n = Vec::with_capacity(4);
        if x > 0 {
            n.push(c - 1);
        }
        if x + 1 < g {
            n.push(c + 1);
        }
        if y > 0 {
            n.push(c - g);
        }
        if y + 1 < g {
            n.push(c + g);
        }
        n
    };
    // contiguous same-class flood fill within the component
    let mut group_of: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for &start in cells {
        if group_of.contains_key(&start) {
            continue;
        }
        let label = argmax(start);
        let gi = groups.len();
        let mut stack = vec![start];
        group_of.insert(start, gi);
        let mut members = Vec::new();
        while let Some(c) = stack.pop() {
            members.push(c);
            for n in neighbours(c) {
                if in_comp.contains(&n) && !group_of.contains_key(&n) && argmax(n) == label {
                    group_of.insert(n, gi);
                    stack.push(n);
                }
            }
        }
        groups.push(members);
    }
    if groups.len() <= 1 {
        return groups;
    }
    // absorb singleton fragments into an adjacent larger group
    let mut absorbed: Vec<Option<usize>> = vec![None; groups.len()];
    for (gi, members) in groups.iter().enumerate() {
        if members.len() == 1 {
            let c = members[0];
            if let Some(&n) = neighbours(c)
                .iter()
                .find(|n| in_comp.contains(n) && group_of[n] != gi && groups[group_of[n]].len() > 1)
            {
                absorbed[gi] = Some(group_of[&n]);
            }
        }
    }
    let mut merged: Vec<Vec<usize>> = vec![Vec::new(); groups.len()];
    for (gi, members) in groups.into_iter().enumerate() {
        let target = absorbed[gi].unwrap_or(gi);
        merged[target].extend(members);
    }
    merged.into_iter().filter(|m| !m.is_empty()).collect()
}

fn region_from_cells(cells: &[usize], h: &FrameHeads<'_>) -> PredBox {
    let g = h.grid;
    let k = h.num_classes;
    let (mut x0, mut y0, mut x1, mut y1) = (usize::MAX, usize::MAX, 0, 0);
    let mut class_mass = vec![0.0f64; k];
    let mut total_energy = 0.0f64;
    let mut max_loc = 0.0f64;
    for &c in cells {
        let (x, y) = (c % g, c / g);
        x0 = x0.min(x);
        y0 = y0.min(y);
        x1 = x1.max(x);
        y1 = y1.max(y);
        let e = h.energy[c].max(1e-6) as f64;
        total_energy += e;
        max_loc = max_loc.max(h.loc_conf[c] as f64);
        for j in 0..k {
            class_mass[j] += e * h.cls_prob[c * k + j] as f64;
        }
    }
    let mut best = (0usize, f64::NEG_INFINITY);
    for (j, &m) in class_mass.iter().enumerate() {
        if m > best.1 {
            best = (j, m);
        }
    }
    let cls_conf = if total_energy > 0.0 { best.1 / total_energy } else { 0.0 };
    PredBox {
        rect: GtBox { x0, y0, x1, y1, class: best.0, id: 0 },
        class: best.0,
        cls_conf,
        loc_conf: max_loc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Owned {
        loc: Vec<f32>,
        cls: Vec<f32>,
        energy: Vec<f32>,
    }

    fn empty(grid: usize, k: usize) -> Owned {
        Owned {
            loc: vec![0.0; grid * grid],
            cls: vec![1.0 / k as f32; grid * grid * k],
            energy: vec![0.01; grid * grid],
        }
    }

    fn heads<'a>(o: &'a Owned, grid: usize, k: usize) -> FrameHeads<'a> {
        FrameHeads { loc_conf: &o.loc, cls_prob: &o.cls, energy: &o.energy, grid, num_classes: k }
    }

    fn paint(o: &mut Owned, _grid: usize, k: usize, cells: &[usize], class: usize, conf: f32) {
        for &c in cells {
            o.loc[c] = 0.9;
            o.energy[c] = 1.0;
            for j in 0..k {
                o.cls[c * k + j] = if j == class { conf } else { (1.0 - conf) / (k - 1) as f32 };
            }
        }
    }

    #[test]
    fn empty_frame_yields_no_regions() {
        let o = empty(8, 4);
        assert!(regions_from_heads(&heads(&o, 8, 4), 0.5).is_empty());
    }

    #[test]
    fn single_blob_forms_one_region() {
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        // 2x2 blob at (2,2)..(3,3): cells 18,19,26,27
        paint(&mut o, g, k, &[18, 19, 26, 27], 2, 0.8);
        let regions = regions_from_heads(&heads(&o, g, k), 0.5);
        assert_eq!(regions.len(), 1);
        let r = &regions[0];
        assert_eq!((r.rect.x0, r.rect.y0, r.rect.x1, r.rect.y1), (2, 2, 3, 3));
        assert_eq!(r.class, 2);
        assert!(r.cls_conf > 0.7);
        // painted with 0.9f32, which sits just below 0.9 in f64
        assert!(r.loc_conf >= 0.89);
    }

    #[test]
    fn disjoint_blobs_form_separate_regions() {
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        paint(&mut o, g, k, &[0], 1, 0.9);
        paint(&mut o, g, k, &[63], 3, 0.9);
        let mut regions = regions_from_heads(&heads(&o, g, k), 0.5);
        regions.sort_by_key(|r| r.rect.x0);
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].class, 1);
        assert_eq!(regions[1].class, 3);
    }

    #[test]
    fn diagonal_cells_are_not_connected() {
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        paint(&mut o, g, k, &[0, 9], 1, 0.9); // (0,0) and (1,1)
        let regions = regions_from_heads(&heads(&o, g, k), 0.5);
        assert_eq!(regions.len(), 2);
    }

    #[test]
    fn touching_blobs_of_different_class_are_split() {
        // adjacent cells with different argmax classes form one connected
        // component but must be split into two regions (two objects)
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        paint(&mut o, g, k, &[10, 11], 1, 0.9);
        paint(&mut o, g, k, &[12, 13], 2, 0.9);
        let mut regions = regions_from_heads(&heads(&o, g, k), 0.5);
        regions.sort_by_key(|r| r.rect.x0);
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].class, 1);
        assert_eq!(regions[1].class, 2);
        assert_eq!((regions[0].rect.x0, regions[0].rect.x1), (2, 3));
    }

    #[test]
    fn singleton_class_flip_is_absorbed() {
        // one noisy cell inside a blob flips class; it must not become its
        // own 1-cell region
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        paint(&mut o, g, k, &[10, 11, 12, 18, 19, 20], 1, 0.9);
        paint(&mut o, g, k, &[11], 3, 0.9); // flip the middle cell
        let regions = regions_from_heads(&heads(&o, g, k), 0.5);
        assert_eq!(regions.len(), 1, "{regions:?}");
        assert_eq!(regions[0].class, 1);
    }

    #[test]
    fn prop_painted_blobs_round_trip_through_region_extraction() {
        // Round-trip invariant: painting disjoint, non-touching rectangular
        // blobs and running extraction must recover exactly one region per
        // blob, with the blob's bounds and class — all regions inside the
        // frame, no duplicate and no dropped labels.
        crate::util::prop::prop_check(120, 33, |g| {
            let (grid, k) = (12usize, 5usize);
            let mut o = empty(grid, k);
            let mut used = vec![false; grid * grid];
            let mut painted: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
            for _ in 0..g.usize_in(1, 5) {
                let w = g.usize_in(1, 3);
                let hgt = g.usize_in(1, 3);
                let x0 = g.usize_in(0, grid - w);
                let y0 = g.usize_in(0, grid - hgt);
                let (x1, y1) = (x0 + w - 1, y0 + hgt - 1);
                // keep a 1-cell moat around every blob so none touch (not
                // even diagonally) and same-class merging cannot occur
                let mut clash = false;
                for y in y0.saturating_sub(1)..=(y1 + 1).min(grid - 1) {
                    for x in x0.saturating_sub(1)..=(x1 + 1).min(grid - 1) {
                        clash |= used[y * grid + x];
                    }
                }
                if clash {
                    continue;
                }
                let class = g.usize_in(0, k - 1);
                let mut cells = Vec::new();
                for y in y0..=y1 {
                    for x in x0..=x1 {
                        used[y * grid + x] = true;
                        cells.push(y * grid + x);
                    }
                }
                paint(&mut o, grid, k, &cells, class, 0.9);
                painted.push((x0, y0, x1, y1, class));
            }
            let regions = regions_from_heads(&heads(&o, grid, k), 0.5);
            if regions.len() != painted.len() {
                return Err(format!(
                    "{} blobs -> {} regions: {painted:?}",
                    painted.len(),
                    regions.len()
                ));
            }
            for r in &regions {
                let rect = &r.rect;
                if rect.x1 >= grid || rect.y1 >= grid || rect.x0 > rect.x1 || rect.y0 > rect.y1 {
                    return Err(format!("region out of frame bounds: {:?}", r.rect));
                }
            }
            for &(x0, y0, x1, y1, class) in &painted {
                let hits = regions
                    .iter()
                    .filter(|r| {
                        (r.rect.x0, r.rect.y0, r.rect.x1, r.rect.y1) == (x0, y0, x1, y1)
                            && r.class == class
                    })
                    .count();
                if hits != 1 {
                    return Err(format!(
                        "blob {:?} recovered {hits} times (duplicate/dropped label)",
                        (x0, y0, x1, y1, class)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn theta_loc_gates_regions() {
        let (g, k) = (8, 4);
        let mut o = empty(g, k);
        paint(&mut o, g, k, &[20], 0, 0.9);
        o.loc[20] = 0.4;
        assert!(regions_from_heads(&heads(&o, g, k), 0.5).is_empty());
        assert_eq!(regions_from_heads(&heads(&o, g, k), 0.3).len(), 1);
    }
}
