//! Cloud-fog pipeline state: protocol configuration, the global
//! incremental learner, and per-camera HITL sessions.
//!
//! The per-chunk High-and-Low state machine (Fig. 6) that used to live
//! here as a 9-argument synchronous function is now the event-driven
//! [`crate::serverless::executor`]: each protocol step is a discrete
//! [`Stage`](crate::serverless::executor::Stage) event on a virtual-clock
//! queue, bound to a registered function in the
//! [`FunctionRegistry`](crate::serverless::registry::FunctionRegistry).
//! The [`Coordinator`] is the state the executor drives: thresholds and
//! qualities ([`ProtocolConfig`]), the Eq. (8)/(9) learner shared by every
//! camera, and one [`CameraSession`] of HITL label state per camera.

use std::collections::BTreeMap;

use crate::hitl::collector::LabeledCrop;
use crate::hitl::{CameraSession, IncrementalLearner};
use crate::metrics::f1::PredBox;
use crate::protocol::ProtocolConfig;

/// Result of coordinating one chunk (every system produces this shape so
/// pipelines can score uniformly).
#[derive(Debug, Clone)]
pub struct ChunkOutcome {
    /// Final labels per keyframe.
    pub per_frame: Vec<Vec<PredBox>>,
    /// Virtual time at which the chunk's last label was produced.
    pub done: f64,
    pub uncertain_regions: u64,
    pub fallback_used: bool,
}

/// The VPaaS pipeline state the executor drives.
pub struct Coordinator {
    pub cfg: ProtocolConfig,
    /// The global incremental learner — one classifier shared by every
    /// camera (its last layer fans out to all fog shards on update).
    pub learner: IncrementalLearner,
    /// Per-camera HITL sessions; a training batch never mixes cameras.
    sessions: BTreeMap<usize, CameraSession>,
    /// Enable the HITL loop (Fig. 13 ablates this).
    pub hitl_enabled: bool,
    /// Train on the cloud GPU co-located with inference (Fig. 13b).
    pub colocate_training: bool,
    /// Use the Eq. (9) snapshot ensemble as a second opinion on crops the
    /// current classifier rejects (§V-B's "weighted combined" prediction).
    pub use_ensemble: bool,
}

impl Coordinator {
    pub fn new(cfg: ProtocolConfig, learner: IncrementalLearner) -> Self {
        Coordinator {
            cfg,
            learner,
            sessions: BTreeMap::new(),
            hitl_enabled: true,
            colocate_training: true,
            use_ensemble: true,
        }
    }

    /// This camera's HITL session, created on first use.
    pub fn session_mut(&mut self, camera: usize) -> &mut CameraSession {
        self.sessions.entry(camera).or_insert_with(|| CameraSession::new(camera))
    }

    /// Take a full training batch from `camera`'s session if it has one —
    /// without creating a session for a camera that never buffered a
    /// label (sessions exist only for label-contributing cameras, which
    /// is what [`crate::metrics::meters::RunMetrics::sessions_retired`]
    /// counts).
    pub fn take_batch(&mut self, camera: usize) -> Option<Vec<LabeledCrop>> {
        self.sessions.get_mut(&camera).and_then(CameraSession::take_batch)
    }

    /// All sessions created so far, in camera order.
    pub fn sessions(&self) -> impl Iterator<Item = &CameraSession> {
        self.sessions.values()
    }

    /// Sessions currently held (cameras that have contributed HITL state).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Retire one camera's session (the camera left the fleet). Any
    /// sub-batch leftover labels are dropped — they never trained, so
    /// retiring cannot change what the learner saw.
    pub fn retire_session(&mut self, camera: usize) -> Option<CameraSession> {
        self.sessions.remove(&camera)
    }

    /// Retire every session at end of run so no camera's state outlives
    /// its stream; returns how many sessions were retired.
    pub fn retire_all(&mut self) -> u64 {
        let n = self.sessions.len() as u64;
        self.sessions.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InferenceService;
    use crate::sim::params::SimParams;

    #[test]
    fn sessions_are_created_per_camera_and_learner_is_shared() {
        let svc = InferenceService::start().unwrap();
        let p = SimParams::load().unwrap();
        let learner =
            IncrementalLearner::new(svc.handle(), p.cls_last0.clone(), p.il_batch, p.num_classes);
        let mut c = Coordinator::new(ProtocolConfig::default(), learner);
        c.session_mut(3).submit(vec![0.0; p.cls_feat], 0);
        c.session_mut(7).submit(vec![1.0; p.cls_feat], 1);
        assert_eq!(c.sessions().count(), 2);
        assert_eq!(c.session_mut(3).pending(), 1);
        assert_eq!(c.session_mut(7).pending(), 1);
        assert_eq!(c.learner.updates, 0);
        // draining a camera that never buffered a label must not create a
        // session for it
        assert!(c.take_batch(99).is_none());
        assert_eq!(c.active_sessions(), 2);
        // a churned camera's session retires with its leftovers
        let gone = c.retire_session(3).expect("session 3 existed");
        assert_eq!(gone.pending(), 1);
        assert_eq!(c.active_sessions(), 1);
        assert!(c.retire_session(3).is_none());
        assert_eq!(c.retire_all(), 1);
        assert_eq!(c.active_sessions(), 0);
    }
}
