//! The cloud-fog coordinator: the per-chunk High-and-Low streaming state
//! machine (Fig. 6), plus the HITL hook (Fig. 8) and the outage fallback
//! (Fig. 15).
//!
//! Per chunk:
//! 1. client → fog over the LAN (high quality; negligible cost, co-located)
//! 2. fog re-encodes to LOW and ships to the cloud over the WAN
//! 3. cloud runs the heavy detector on the LOW stream
//! 4. confident boxes become final labels; filtered uncertain-region
//!    *coordinates* go back to the fog (bytes, not pixels)
//! 5. fog crops its cached high-quality frames and classifies the crops
//!    under dynamic batching
//! 6. a budgeted fraction of crops gets human labels; full batches trigger
//!    the Eq. (8) auto-trainer which swaps the fog classifier's last layer
//!
//! If the WAN is down at step 2 the fog falls back to its lite detector and
//! keeps serving (reduced accuracy), exactly Fig. 15.

use anyhow::Result;

use crate::cloud::CloudServer;
use crate::fog::FogNode;
use crate::hitl::{DataCollector, IncrementalLearner};
use crate::metrics::f1::PredBox;
use crate::metrics::meters::RunMetrics;
use crate::protocol::post::regions_from_heads;
use crate::protocol::{split_regions, ProtocolConfig};
use crate::sim::human::Annotator;
use crate::sim::net::Topology;
use crate::sim::params::SimParams;
use crate::sim::video::codec;
use crate::sim::video::{render_frame, render_region_crop, Chunk, Quality};

/// Result of coordinating one chunk.
#[derive(Debug, Clone)]
pub struct ChunkOutcome {
    /// Final labels per keyframe.
    pub per_frame: Vec<Vec<PredBox>>,
    /// Virtual time at which the chunk's last label was produced.
    pub done: f64,
    pub uncertain_regions: u64,
    pub fallback_used: bool,
}

/// The VPaaS coordinator with its HITL state.
pub struct Coordinator {
    pub cfg: ProtocolConfig,
    pub collector: DataCollector,
    pub learner: IncrementalLearner,
    /// Enable the HITL loop (Fig. 13 ablates this).
    pub hitl_enabled: bool,
    /// Train on the cloud GPU co-located with inference (Fig. 13b).
    pub colocate_training: bool,
    /// Use the Eq. (9) snapshot ensemble as a second opinion on crops the
    /// current classifier rejects (§V-B's "weighted combined" prediction).
    pub use_ensemble: bool,
}

impl Coordinator {
    pub fn new(cfg: ProtocolConfig, learner: IncrementalLearner) -> Self {
        Coordinator {
            cfg,
            collector: DataCollector::new(learner_batch_trigger()),
            learner,
            hitl_enabled: true,
            colocate_training: true,
            use_ensemble: true,
        }
    }

    /// Process one chunk end to end. `t_offset` shifts the video's local
    /// capture clock into the global run timeline; `phi` is the drift angle.
    #[allow(clippy::too_many_arguments)]
    pub fn process_chunk(
        &mut self,
        chunk: &Chunk,
        phi: f64,
        t_offset: f64,
        p: &SimParams,
        topo: &mut Topology,
        cloud: &mut CloudServer,
        fog: &mut FogNode,
        annotator: &mut Annotator,
        metrics: &mut RunMetrics,
    ) -> Result<ChunkOutcome> {
        let n = chunk.frames.len();
        let captured = t_offset + chunk.t_capture + chunk.duration();

        // 1. client → fog LAN (high quality). Co-located: cheap, not WAN.
        let hi_bytes = n as f64 * codec::frame_bytes(Quality::ORIGINAL, p);
        let at_fog = topo
            .lan
            .transfer(hi_bytes, captured)
            .expect("LAN has no outage schedule");

        // 2. fog quality control: re-encode to LOW.
        let qc_done = fog.quality_control(n, at_fog);

        // 3. ship LOW stream to the cloud.
        let low_bytes = n as f64 * codec::frame_bytes(self.cfg.low_quality, p);
        let at_cloud = match topo.wan_up.transfer(low_bytes, qc_done) {
            Ok(t) => t,
            Err(down) => {
                // Fallback: fog lite detector on the cached high stream.
                return self.process_chunk_fog_only(chunk, phi, t_offset, p, fog, metrics, down.detected_at);
            }
        };
        metrics.bandwidth.add(low_bytes);

        // 4. cloud detection on the LOW stream.
        let low_frames: Vec<_> = chunk
            .frames
            .iter()
            .map(|f| render_frame(f, self.cfg.low_quality, phi, p))
            .collect();
        let (heads, det_timing) = cloud.detect_chunk(&low_frames, at_cloud, "detector")?;

        // 5. split into confident labels + uncertain region coordinates.
        let mut per_frame: Vec<Vec<PredBox>> = Vec::with_capacity(n);
        let mut uncertain_per_frame: Vec<Vec<PredBox>> = Vec::with_capacity(n);
        let mut total_regions = 0usize;
        for h in &heads {
            let regions = regions_from_heads(&h.as_heads(), self.cfg.filter.theta_loc);
            let (confident, uncertain) =
                split_regions(&regions, self.cfg.theta_cls, &self.cfg.filter, p.grid);
            total_regions += confident.len() + uncertain.len();
            per_frame.push(confident);
            uncertain_per_frame.push(uncertain);
        }

        // 6. coordinates (bytes) back to the fog.
        let fb_bytes = codec::feedback_bytes(total_regions);
        let at_fog_again = match topo.wan_down.transfer(fb_bytes, det_timing.done) {
            Ok(t) => t,
            Err(down) => {
                return self.process_chunk_fog_only(chunk, phi, t_offset, p, fog, metrics, down.detected_at);
            }
        };
        metrics.bandwidth.add(fb_bytes);

        // 7. fog crops the cached HIGH-quality frames and classifies.
        let mut crops = Vec::new();
        let mut crop_ref = Vec::new(); // (frame idx, region)
        for (fi, regions) in uncertain_per_frame.iter().enumerate() {
            for r in regions {
                crops.push(render_region_crop(
                    &chunk.frames[fi],
                    &r.rect,
                    self.cfg.crop_quality,
                    phi,
                    p,
                ));
                crop_ref.push((fi, *r));
            }
        }
        let (results, feats, cls_done) = fog.classify_crops(&crops, at_fog_again)?;
        metrics.fog_regions += crops.len() as u64;

        for (((fi, region), res), f) in crop_ref.iter().zip(&results).zip(&feats) {
            if res.prob >= self.cfg.theta_fog {
                per_frame[*fi].push(PredBox {
                    rect: region.rect,
                    class: res.class,
                    cls_conf: res.prob,
                    loc_conf: region.loc_conf,
                });
            } else if self.use_ensemble {
                // Eq. (9): the snapshot ensemble votes on borderline crops.
                if let Some((class, score)) = self.learner.ensemble_classify(f) {
                    if score > 0.0 {
                        per_frame[*fi].push(PredBox {
                            rect: region.rect,
                            class,
                            cls_conf: self.cfg.theta_fog, // borderline accept
                            loc_conf: region.loc_conf,
                        });
                    }
                }
            }
        }

        // 8. HITL: offer crops to the annotator, train on full batches.
        if self.hitl_enabled {
            for ((fi, region), f) in crop_ref.iter().zip(&feats) {
                // the human looks at the crop; their label is the dominant
                // true object under the region (skip pure-background crops)
                let truth = &chunk.frames[*fi];
                let gt = truth
                    .objects
                    .iter()
                    .map(|o| (o, region.rect.iou(&o.gt)))
                    .filter(|(_, iou)| *iou >= 0.2)
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                if let Some((obj, _)) = gt {
                    if let Some(label) = annotator.offer(obj.gt.class) {
                        metrics.labels_used += 1;
                        self.collector.submit(f.clone(), label.class);
                    }
                }
            }
            while let Some(batch) = self.collector.take_batch() {
                self.learner.update(&batch)?;
                fog.set_last_layer(self.learner.w_last.clone());
                if self.colocate_training {
                    cloud.train_burst(cls_done, 1);
                }
            }
        }

        let done = cls_done.max(det_timing.done);
        for (i, _) in chunk.frames.iter().enumerate() {
            metrics
                .latency
                .record(done - (t_offset + chunk.frame_time(i)));
        }
        metrics.chunks += 1;

        Ok(ChunkOutcome {
            per_frame,
            done,
            uncertain_regions: crops.len() as u64,
            fallback_used: false,
        })
    }

    /// Serve a chunk entirely at the fog with the lite detector — used when
    /// the cloud is unreachable (Fig. 15) or a policy routes to the fog.
    #[allow(clippy::too_many_arguments)]
    pub fn process_chunk_fog_only(
        &mut self,
        chunk: &Chunk,
        phi: f64,
        t_offset: f64,
        p: &SimParams,
        fog: &mut FogNode,
        metrics: &mut RunMetrics,
        detected_at: f64,
    ) -> Result<ChunkOutcome> {
        let hi_frames: Vec<_> = chunk
            .frames
            .iter()
            .map(|f| render_frame(f, Quality::ORIGINAL, phi, p))
            .collect();
        let (heads, done) = fog.fallback_detect(&hi_frames, detected_at, p.grid)?;
        let mut per_frame = Vec::with_capacity(heads.len());
        for h in &heads {
            let regions = regions_from_heads(&h.as_heads(), self.cfg.filter.theta_loc);
            // single-stage fallback: take argmax labels directly
            per_frame.push(regions);
        }
        for (i, _) in chunk.frames.iter().enumerate() {
            metrics
                .latency
                .record(done - (t_offset + chunk.frame_time(i)));
        }
        metrics.chunks += 1;
        Ok(ChunkOutcome {
            per_frame,
            done,
            uncertain_regions: 0,
            fallback_used: true,
        })
    }
}

fn learner_batch_trigger() -> usize {
    // The paper trains with batch size 4 (§VI-C "HITL Overhead").
    4
}
