//! The §IV-B uncertain-region filter.
//!
//! After the cloud detector runs on the LOW-quality stream, its regions are
//! split into:
//!
//! * **confident** — class confidence ≥ θ_cls: shipped back as final labels;
//! * **uncertain** — the rest, kept only when (1) location confidence
//!   ≥ θ_loc, (2) IoU against every confident box < θ_iou (not a duplicate
//!   of something already recognized), and (3) region area ≤ θ_back of the
//!   frame (giant regions are background). Their *coordinates* (bytes, not
//!   pixels) go back to the fog for high-quality crop classification.

use crate::metrics::f1::PredBox;

#[derive(Debug, Clone, Copy)]
pub struct FilterConfig {
    pub theta_loc: f64,
    pub theta_iou: f64,
    /// Maximum region area as a fraction of the frame.
    pub theta_back: f64,
}

impl Default for FilterConfig {
    fn default() -> Self {
        FilterConfig { theta_loc: 0.5, theta_iou: 0.3, theta_back: 0.25 }
    }
}

/// Split detector regions into (confident labels, uncertain regions).
pub fn split_regions(
    regions: &[PredBox],
    theta_cls: f64,
    cfg: &FilterConfig,
    grid: usize,
) -> (Vec<PredBox>, Vec<PredBox>) {
    let frame_area = (grid * grid) as f64;
    let confident: Vec<PredBox> = regions
        .iter()
        .filter(|r| r.cls_conf >= theta_cls)
        .copied()
        .collect();
    let uncertain = regions
        .iter()
        .filter(|r| r.cls_conf < theta_cls)
        .filter(|r| r.loc_conf >= cfg.theta_loc)
        .filter(|r| {
            confident
                .iter()
                .all(|c| r.rect.iou(&c.rect) < cfg.theta_iou)
        })
        .filter(|r| (r.rect.area() as f64) / frame_area <= cfg.theta_back)
        .copied()
        .collect();
    (confident, uncertain)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::video::scene::GtBox;

    fn region(x0: usize, size: usize, class: usize, cls_conf: f64, loc_conf: f64) -> PredBox {
        PredBox {
            rect: GtBox { x0, y0: x0, x1: x0 + size - 1, y1: x0 + size - 1, class, id: 0 },
            class,
            cls_conf,
            loc_conf,
        }
    }

    fn cfg() -> FilterConfig {
        FilterConfig::default()
    }

    #[test]
    fn confident_regions_become_labels() {
        let regions = vec![region(1, 2, 3, 0.9, 0.9), region(6, 2, 1, 0.4, 0.9)];
        let (conf, unc) = split_regions(&regions, 0.7, &cfg(), 16);
        assert_eq!(conf.len(), 1);
        assert_eq!(conf[0].class, 3);
        assert_eq!(unc.len(), 1);
        assert_eq!(unc[0].class, 1);
    }

    #[test]
    fn low_loc_conf_uncertain_regions_drop() {
        let regions = vec![region(1, 2, 3, 0.4, 0.3)];
        let (conf, unc) = split_regions(&regions, 0.7, &cfg(), 16);
        assert!(conf.is_empty());
        assert!(unc.is_empty());
    }

    #[test]
    fn duplicates_of_confident_boxes_drop() {
        // uncertain region heavily overlapping a confident one
        let mut dup = region(1, 3, 2, 0.5, 0.9);
        dup.rect = GtBox { x0: 1, y0: 1, x1: 3, y1: 3, class: 2, id: 0 };
        let confident = PredBox {
            rect: GtBox { x0: 1, y0: 1, x1: 3, y1: 3, class: 5, id: 0 },
            class: 5,
            cls_conf: 0.95,
            loc_conf: 0.9,
        };
        let (conf, unc) = split_regions(&[confident, dup], 0.7, &cfg(), 16);
        assert_eq!(conf.len(), 1);
        assert!(unc.is_empty(), "duplicate region must be filtered");
    }

    #[test]
    fn background_sized_regions_drop() {
        // 9x9 = 81 cells of a 16x16 frame (256) = 31.6% > 25%
        let big = region(0, 9, 0, 0.4, 0.9);
        let (_, unc) = split_regions(&[big], 0.7, &cfg(), 16);
        assert!(unc.is_empty());
        // 6x6 = 36/256 = 14% passes
        let ok = region(0, 6, 0, 0.4, 0.9);
        let (_, unc) = split_regions(&[ok], 0.7, &cfg(), 16);
        assert_eq!(unc.len(), 1);
    }

    #[test]
    fn prop_split_is_a_partition_of_kept_regions() {
        crate::util::prop::prop_check(100, 21, |g| {
            let regions: Vec<PredBox> = (0..g.usize_in(0, 12))
                .map(|_| {
                    let x = g.usize_in(0, 12);
                    let s = g.usize_in(1, 4);
                    let (c1, c2) = (g.f64_range(0.0, 1.0), g.f64_range(0.0, 1.0));
                    region(x.min(12), s, g.usize_in(0, 7), c1, c2)
                })
                .collect();
            let (conf, unc) = split_regions(&regions, 0.7, &cfg(), 16);
            if conf.len() + unc.len() > regions.len() {
                return Err("split invented regions".into());
            }
            for c in &conf {
                if c.cls_conf < 0.7 {
                    return Err("unconfident region in confident set".into());
                }
            }
            for u in &unc {
                if u.cls_conf >= 0.7 {
                    return Err("confident region in uncertain set".into());
                }
                if u.loc_conf < 0.5 {
                    return Err("low-loc region kept".into());
                }
            }
            Ok(())
        });
    }
}
