//! # VPaaS — a serverless cloud-fog platform for DNN video analytics
//!
//! Reproduction of Zhang et al., *"A Serverless Cloud-Fog Platform for
//! DNN-Based Video Analytics with Incremental Learning"* (2021), as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the High-and-Low streaming
//!   protocol, the serverless cloud/fog servers, HITL incremental learning,
//!   the baselines it is evaluated against, and every substrate the paper's
//!   testbed provided (scene/codec/network/human simulators).
//! * **L2/L1 (python/, build-time only)** — JAX models + Pallas kernels.
//!   With an XLA toolchain they AOT-lower to HLO text artifacts executed
//!   via PJRT; in this environment [`runtime`] instead runs a pure-Rust
//!   reference implementation of the same math, driven by the exported
//!   `artifacts/manifest.txt` + `constants.txt` (see
//!   `python/compile/export_reference.py`). Python never runs on the
//!   request path either way.
//!
//! ## Event-driven pipeline execution
//!
//! A video pipeline is "a set of functions … orchestrated" (Fig. 2), and
//! that is literally how the request path runs
//! ([`serverless::executor`]):
//!
//! * **Stages as events** — each Fig. 6 step (client→fog LAN, fog QC,
//!   WAN uplink, cloud detect, coordinate downlink, fog crop-classify,
//!   HITL) is a discrete [`serverless::executor::Stage`] event on a
//!   virtual-clock queue. Within a dispatch wave the globally earliest
//!   event runs first, so chunk *k+1*'s WAN uplink overlaps chunk *k*'s
//!   cloud GPU phase and shared resources serve in virtual-arrival order
//!   ([`serverless::executor::DispatchMode::Sequential`] reproduces the
//!   old per-chunk state machine for A/B makespan comparisons —
//!   `BENCH_overlap.json` from `cargo bench --bench fig16_scalability`
//!   tracks the gap).
//! * **Run-scoped streaming** — under
//!   [`serverless::executor::DispatchMode::Streaming`] the queue spans
//!   the whole run: the pipeline admits each dispatch wave into one
//!   [`serverless::executor::StreamingSession`], consecutive waves
//!   overlap, and the HITL wave barrier survives as an explicit
//!   [`serverless::executor::Stage::Barrier`] event, so labels stay
//!   bit-identical across all dispatch modes. `BENCH_stream.json`
//!   compares the three modes across uniform / bursty / churn workload
//!   profiles ([`sim::video::WorkloadProfile`]).
//! * **Functions are the unit of execution** — every executable stage is
//!   bound to a [`serverless::registry::FunctionRegistry`] entry
//!   (`reencode_low`, `detect`, `classify_crops`, `il_update`, plus any
//!   bound `PostProcess` functions). Overriding an entry with
//!   [`serverless::registry::FunctionRegistry::bind`] changes what the
//!   pipeline runs — `examples/quickstart.rs` rebinds `detect` to the
//!   lite artifact and watches the output move.
//! * **Context-struct API** — per-chunk entry points take a
//!   [`serverless::executor::ChunkJob`] plus a
//!   [`serverless::executor::StageCtx`] of testbed borrows; the old
//!   9-argument `process_chunk` signature is gone everywhere (baselines
//!   use the analogous [`baselines::ChunkEnv`]).
//! * **Per-camera HITL sessions** — the coordinator keeps one
//!   [`hitl::CameraSession`] per camera, so a training batch never mixes
//!   cameras, while the [`hitl::IncrementalLearner`] stays global and its
//!   updates fan out to every fog shard.
//!
//! ## One generic tier control plane
//!
//! Both scale-out tiers are instantiations of
//! [`serverless::pool::TierPool`] over a
//! [`serverless::pool::PoolWorker`]: seeded least-loaded routing (tie
//! breaks drawn only on real ties), `admit`/`complete`/`abort` in-flight
//! accounting, gauge publication, and a bounded provisioner that only
//! retires an idle tail worker and carries retired workers' bills over —
//! one implementation, so the fog and cloud tiers cannot drift.
//!
//! ## Sharded multi-fog scale-out
//!
//! The request path scales across a pool of fog nodes
//! ([`serverless::scheduler`]):
//!
//! * **Shard pool** — [`serverless::scheduler::FogShardPool`] owns N
//!   [`fog::FogNode`] shards; each chunk routes to the least-backlog shard
//!   over that shard's own LAN segment
//!   ([`sim::net::Topology::fog_lans`]), and the deployment
//!   [`serverless::Policy`] (fed the shard's `fog_backlog_s`) decides
//!   cloud-protocol vs fog-only dispatch.
//! * **Cross-camera waves** — [`pipeline::Harness::run`] streams all of a
//!   dataset's videos concurrently, merges chunks in capture order and
//!   groups them into dispatch waves through
//!   [`serving::batcher::DynamicBatcher`]; each chunk's shard LAN is held
//!   until its wave dispatches, so the wave wait is real virtual-clock
//!   latency and the shared links/GPU queues see grouped arrivals.
//! * **Provisioner** — the pool publishes `fog_backlog_s` /
//!   `fog_shards` gauges into [`serverless::GlobalMonitor`]; a
//!   backlog-threshold autoscaler grows/shrinks the pool (Fig. 16's
//!   provisioner applied to the fog tier).
//! * **Determinism** — every RNG stream (per-shard link jitter, routing
//!   tie-breaks) derives from the run seed via [`util::rng::Pcg32`], so
//!   sharded runs are bit-reproducible; `tests/scheduler.rs` asserts it.
//!
//! ## Sharded cloud GPU tier and SLO-aware admission
//!
//! The cloud tier scales through the same pool abstraction
//! ([`cloud::CloudGpuPool`]): `RunConfig::gpus` single-GPU
//! [`cloud::CloudServer`] workers behind one control plane, with
//! least-queue-wait admission for `CloudDetect` and `il_update` stage
//! events (plus a pooled SR entry point), per-worker `ExecTiming`
//! queues, `gpu_queue_s`/`gpu_workers`
//! gauges, and a bounded provisioner that never retires a worker holding
//! queued events (a 1-worker pool reproduces the legacy single-server
//! cloud bit-for-bit). On top of it, `RunConfig::slo_ms` enables
//! freshness-SLO admission: a chunk whose projected capture→classify
//! latency ([`pipeline::project_freshness`]) misses the target uplinks at
//! the **highest feasible rung of the configured rate ladder**
//! ([`sim::video::codec::Quality::LADDER`], searched greedily by
//! [`pipeline::plan_uplink`]; `RunConfig::ladder`, CLI `--ladder`,
//! `[app] ladder`) or is refused when even the lowest rung misses, and a
//! chunk that still finishes stale is never scored — counted in
//! `RunMetrics::{chunks_degraded, chunks_dropped}` (per-rung plans in
//! `degrade_planned`). The same projection couples into routing: the
//! executor admits detects to a worker whose projected completion meets
//! the deadline (`CloudGpuPool::admit_within`), and the
//! `gpu_saturation_aware` policy reads the projection instead of the
//! lagging queue-wait EWMA. With the SLO disabled the whole pipeline is
//! content-invariant across dispatch mode × fog shards × cloud GPUs ×
//! workload profile
//! ([`metrics::meters::RunMetrics::content_fingerprint`],
//! `tests/invariance.rs`), ladder configured or not.
//!
//! Run the scale-out benchmarks with
//! `cargo bench --bench fig16_scalability` (or
//! `cargo run --release -- figures --id fig16`), which sweep fog shard
//! counts and cloud GPU worker counts {1, 2, 4, 8} and report
//! virtual-time throughput (`BENCH_overlap.json`, `BENCH_stream.json`,
//! `BENCH_gpu.json`), plus the SLO/cost frontier sweep
//! (`BENCH_slo.json`, `pipeline::figures::fig10_slo_frontier`).
//!
//! ## Multi-tenant fair admission
//!
//! VPaaS is a platform, so many developers' pipelines share the two
//! tiers. [`serverless::tenant`] adds the arbitration layer: a
//! [`serverless::tenant::TenantRegistry`] (name, fair-share weight,
//! optional per-tenant `slo_ms` override; CLI `--tenants`, `[tenants]`
//! config section, `tenants` study axis) maps cameras to tenants, and a
//! [`serverless::tenant::FairQueue`] runs start-time fair queueing over
//! DRF-style chunk costs ([`serverless::tenant::chunk_cost`]) between
//! wave formation and pool admission — a work-conserving pure reorder of
//! each dispatch wave, so a bursty tenant queues behind its weighted
//! share instead of starving the fleet (`tests/tenant_fairness.rs`
//! bounds the steady tenant's p99 against FIFO). [`metrics::RunMetrics`]
//! grows per-tenant accounting ([`metrics::TenantMetrics`]) and a Jain
//! fairness index over weight-normalized chunk shares
//! ([`metrics::meters::RunMetrics::jain_fairness`]); single-tenant,
//! `fifo`-mode and equal-weight-balanced registries are byte-identical
//! to the untenanted pipeline (`tests/invariance.rs`), and
//! `studies/tenant_fairness.toml` sweeps weight mixes × arrival mixes
//! into `BENCH_fairness.json`.
//!
//! ## Parallel deterministic execution
//!
//! The event loop stays single-threaded (one virtual clock, one heap),
//! but stage *bodies* — frame rendering, detector math, crop rendering —
//! fan out across a `RunConfig::threads` worker pool
//! ([`util::par::par_map`]), and each wave's cloud-bound frames are
//! prefetched as contiguous slabs through the batched detector artifact
//! variants so a full wave costs a few batched calls instead of one call
//! per chunk. Thread count is a **pure wall-clock knob**: no RNG draw
//! ever happens on a worker thread, parallel results merge back in input
//! order, and admission/timing/billing still happen only at event time —
//! so output is byte-identical at any thread count
//! (`tests/invariance.rs` proves fingerprint, makespan *and* latency
//! bits at threads ∈ {1, 2, 8}; the whole tier-1 suite re-runs under
//! `VPAAS_THREADS=4` in CI). `BENCH_par.json`
//! ([`pipeline::figures::fig16_par_sweep`]) tracks the host wall-clock
//! speedup — the only bench artifact measured on the host clock rather
//! than the virtual one. The full contract is written down in
//! `ARCHITECTURE.md` ("Determinism model"); `README.md` has the
//! quickstart and the `BENCH_*.json` glossary, and `docs/reference.md`
//! the config grammars.
//!
//! ## Declarative scenario studies
//!
//! The [`study`] subsystem turns those sweeps into data: a declarative
//! spec (`rust/studies/*.toml`) names scenario axes, a repeat count and a
//! base seed; it expands into a canonical bit-reproducible trial plan,
//! executes through [`pipeline::Harness`], and aggregates per-cell
//! mean/stddev/95%-CI tables serialized to `BENCH_study.json`
//! ([`study::StudyReport`]). `vpaas study <spec.toml>` runs one from the
//! CLI; `--baseline` compares against a stored report with Welch's
//! t-test, and the cross-commit CI gate (`tests/golden_metrics.rs`) only
//! fails on regressions that are statistically significant *and* beyond
//! per-metric tolerances. The fig16/fig10 sweeps in
//! [`pipeline::figures`] are thin study specs (`repeats = 1`,
//! `seed_mode = fixed`) whose legacy output is preserved byte for byte.
//!
//! Start with `pipeline` for end-to-end drivers, or `examples/quickstart.rs`.

pub mod baselines;
pub mod cloud;
pub mod fog;
pub mod hitl;
pub mod interchange;
pub mod metrics;
pub mod pipeline;
pub mod protocol;
pub mod runtime;
pub mod serverless;
pub mod serving;
pub mod study;
pub mod zoo;
pub mod sim;
pub mod util;
