//! # VPaaS — a serverless cloud-fog platform for DNN video analytics
//!
//! Reproduction of Zhang et al., *"A Serverless Cloud-Fog Platform for
//! DNN-Based Video Analytics with Incremental Learning"* (2021), as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the High-and-Low streaming
//!   protocol, the serverless cloud/fog servers, HITL incremental learning,
//!   the baselines it is evaluated against, and every substrate the paper's
//!   testbed provided (scene/codec/network/human simulators).
//! * **L2/L1 (python/, build-time only)** — JAX models + Pallas kernels,
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`] via PJRT.
//!   Python never runs on the request path.
//!
//! Start with `pipeline` for end-to-end drivers, or `examples/quickstart.rs`.

pub mod baselines;
pub mod cloud;
pub mod fog;
pub mod hitl;
pub mod interchange;
pub mod metrics;
pub mod pipeline;
pub mod protocol;
pub mod runtime;
pub mod serverless;
pub mod serving;
pub mod zoo;
pub mod sim;
pub mod util;
