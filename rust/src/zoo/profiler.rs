//! Model profiler (stateful backend, §III-D): measures each registered
//! model's real PJRT wall time per batch bucket and derives per-device
//! virtual-time estimates via the Fig. 4 device profiles.
//!
//! Registration triggers profiling in the paper ("the model will be
//! profiled; the model with the profiling information will be stored in the
//! cloud model zoo") — `examples/retail_store.rs` shows the same flow.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::interchange::Tensor;
use crate::runtime::InferenceHandle;
use crate::sim::device::DeviceProfile;
use crate::util::clock::Stopwatch;

/// Wall-time measurements for one model across its batch buckets.
#[derive(Debug, Clone, Default)]
pub struct ModelProfile {
    /// bucket -> mean wall seconds per invocation (this host, CPU PJRT).
    pub wall_s: BTreeMap<usize, f64>,
    /// bucket -> items/second throughput.
    pub throughput: BTreeMap<usize, f64>,
}

impl ModelProfile {
    /// Best (highest-throughput) bucket.
    pub fn best_bucket(&self) -> Option<usize> {
        self.throughput
            .iter()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(&b, _)| b)
    }
}

/// Profiles models through the shared inference service.
pub struct Profiler {
    handle: InferenceHandle,
    pub warmup: usize,
    pub iters: usize,
}

impl Profiler {
    pub fn new(handle: InferenceHandle) -> Self {
        Profiler { handle, warmup: 1, iters: 5 }
    }

    /// Build zero inputs matching the artifact's manifest shapes. The
    /// caller provides them normally; zeros are fine for timing.
    fn zero_inputs(&self, specs: &[Vec<usize>]) -> Vec<Tensor> {
        specs.iter().map(|dims| Tensor::zeros(dims.clone())).collect()
    }

    /// Profile one artifact given its input shapes; returns mean seconds.
    pub fn time_artifact(&self, artifact: &str, input_dims: &[Vec<usize>]) -> Result<f64> {
        let inputs = self.zero_inputs(input_dims);
        for _ in 0..self.warmup {
            self.handle.infer(artifact, inputs.clone())?;
        }
        let sw = Stopwatch::new();
        for _ in 0..self.iters {
            self.handle.infer(artifact, inputs.clone())?;
        }
        Ok(sw.elapsed() / self.iters as f64)
    }

    /// Profile a model across its batch buckets. `make_dims(bucket)` maps a
    /// bucket to the artifact input shapes.
    pub fn profile_model(
        &self,
        prefix: &str,
        buckets: &[usize],
        make_dims: impl Fn(usize) -> Vec<Vec<usize>>,
    ) -> Result<ModelProfile> {
        let mut profile = ModelProfile::default();
        for &b in buckets {
            let artifact = format!("{prefix}_b{b}");
            let wall = self.time_artifact(&artifact, &make_dims(b))?;
            profile.wall_s.insert(b, wall);
            profile.throughput.insert(b, b as f64 / wall.max(1e-9));
        }
        Ok(profile)
    }
}

/// Fig. 4 numbers: virtual seconds for an op on a device, given batch size.
/// (The real PJRT wall time above validates *relative* bucket scaling; the
/// device profile sets the absolute scale of the paper's testbed.)
pub fn device_op_seconds(device: &DeviceProfile, base_s: f64, batch: usize) -> f64 {
    device.batched(base_s, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InferenceService;
    use crate::sim::device;

    #[test]
    fn profiles_classifier_buckets() {
        let svc = InferenceService::start().unwrap();
        let prof = Profiler { handle: svc.handle(), warmup: 1, iters: 2 };
        let p = prof
            .profile_model("classifier", &[1, 4], |b| vec![vec![b, 24], vec![49, 8]])
            .unwrap();
        assert_eq!(p.wall_s.len(), 2);
        assert!(p.wall_s[&1] > 0.0);
        // batch-4 must be cheaper per item than 4 batch-1 calls
        assert!(p.wall_s[&4] < 4.0 * p.wall_s[&1]);
        assert!(p.best_bucket().is_some());
    }

    #[test]
    fn device_scaling_matches_fig4_shape() {
        // cloud detection per frame faster than fog by >= 5x
        let cloud = device_op_seconds(&device::CLOUD, device::CLOUD.detect_s, 1);
        let fog = device_op_seconds(&device::FOG, device::FOG.detect_s, 1);
        assert!(fog / cloud >= 5.0);
    }
}
