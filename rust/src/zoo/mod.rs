//! The model zoo + profiler (stateful backend, Fig. 3).
//!
//! Users register models (Fig. 14: `model_zoo.register(...)`); the zoo
//! versions them, stores profiling results, and records where each model is
//! deployed (cloud / fog model-cache). The paper backs this with MongoDB;
//! here it is an in-memory store with the same interface role.

pub mod profiler;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

pub use profiler::{ModelProfile, Profiler};

/// What a model does — determines which pipeline stages may bind to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Detection,
    Classification,
    SuperResolution,
    IncrementalUpdate,
}

/// Where a model is deployed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Cloud,
    Fog,
}

/// A registered model version.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub version: u32,
    pub task: Task,
    /// Artifact name prefix; batch-bucket artifacts are `<prefix>_b<N>`.
    pub artifact_prefix: String,
    pub batch_buckets: Vec<usize>,
    pub profile: Option<ModelProfile>,
    pub placements: Vec<Placement>,
}

impl ModelEntry {
    /// Artifact name for a batch bucket.
    pub fn artifact_for(&self, bucket: usize) -> Result<String> {
        if !self.batch_buckets.contains(&bucket) {
            bail!(
                "{} v{}: no artifact for batch {bucket} (buckets {:?})",
                self.name,
                self.version,
                self.batch_buckets
            );
        }
        Ok(format!("{}_b{bucket}", self.artifact_prefix))
    }
}

/// Versioned model registry.
#[derive(Debug, Default)]
pub struct ModelZoo {
    models: BTreeMap<String, Vec<ModelEntry>>,
}

impl ModelZoo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new version of `name`; returns the assigned version.
    pub fn register(
        &mut self,
        name: &str,
        task: Task,
        artifact_prefix: &str,
        batch_buckets: Vec<usize>,
    ) -> u32 {
        let versions = self.models.entry(name.to_string()).or_default();
        let version = versions.last().map(|e| e.version + 1).unwrap_or(1);
        versions.push(ModelEntry {
            name: name.to_string(),
            version,
            task,
            artifact_prefix: artifact_prefix.to_string(),
            batch_buckets,
            profile: None,
            placements: Vec::new(),
        });
        version
    }

    /// Latest version of a model.
    pub fn latest(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .and_then(|v| v.last())
            .ok_or_else(|| anyhow!("model {name:?} not registered"))
    }

    pub fn get(&self, name: &str, version: u32) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .and_then(|v| v.iter().find(|e| e.version == version))
            .ok_or_else(|| anyhow!("model {name:?} v{version} not registered"))
    }

    /// Record a deployment (the dispatcher calls this).
    pub fn place(&mut self, name: &str, placement: Placement) -> Result<()> {
        let entry = self
            .models
            .get_mut(name)
            .and_then(|v| v.last_mut())
            .ok_or_else(|| anyhow!("model {name:?} not registered"))?;
        if !entry.placements.contains(&placement) {
            entry.placements.push(placement);
        }
        Ok(())
    }

    pub fn attach_profile(&mut self, name: &str, profile: ModelProfile) -> Result<()> {
        let entry = self
            .models
            .get_mut(name)
            .and_then(|v| v.last_mut())
            .ok_or_else(|| anyhow!("model {name:?} not registered"))?;
        entry.profile = Some(profile);
        Ok(())
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(|s| s.as_str())
    }

    pub fn version_count(&self, name: &str) -> usize {
        self.models.get(name).map(|v| v.len()).unwrap_or(0)
    }

    /// Standard registrations for the paper's pipeline.
    pub fn with_standard_models() -> Self {
        let mut zoo = Self::new();
        let buckets = vec![1, 4, 16];
        zoo.register("faster_rcnn_101", Task::Detection, "detector", buckets.clone());
        zoo.register("yolo_lite", Task::Detection, "detector_lite", buckets.clone());
        zoo.register("ova_classifier", Task::Classification, "classifier", buckets.clone());
        zoo.register("carn_sr", Task::SuperResolution, "sr", buckets);
        zoo.register("il_step", Task::IncrementalUpdate, "il_step", vec![]);
        zoo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_lookup() {
        let mut z = ModelZoo::new();
        let v = z.register("m", Task::Detection, "detector", vec![1, 4]);
        assert_eq!(v, 1);
        let e = z.latest("m").unwrap();
        assert_eq!(e.artifact_for(4).unwrap(), "detector_b4");
        assert!(e.artifact_for(16).is_err());
    }

    #[test]
    fn versions_increment() {
        let mut z = ModelZoo::new();
        z.register("m", Task::Classification, "classifier", vec![1]);
        let v2 = z.register("m", Task::Classification, "classifier_v2", vec![1]);
        assert_eq!(v2, 2);
        assert_eq!(z.version_count("m"), 2);
        assert_eq!(z.latest("m").unwrap().artifact_prefix, "classifier_v2");
        assert_eq!(z.get("m", 1).unwrap().artifact_prefix, "classifier");
    }

    #[test]
    fn placements_dedupe() {
        let mut z = ModelZoo::with_standard_models();
        z.place("ova_classifier", Placement::Fog).unwrap();
        z.place("ova_classifier", Placement::Fog).unwrap();
        assert_eq!(z.latest("ova_classifier").unwrap().placements, vec![Placement::Fog]);
    }

    #[test]
    fn missing_model_errors() {
        let z = ModelZoo::new();
        assert!(z.latest("ghost").is_err());
        let mut z = z;
        assert!(z.place("ghost", Placement::Cloud).is_err());
    }

    #[test]
    fn standard_models_cover_pipeline() {
        let z = ModelZoo::with_standard_models();
        for name in ["faster_rcnn_101", "yolo_lite", "ova_classifier", "carn_sr", "il_step"] {
            assert!(z.latest(name).is_ok(), "{name}");
        }
    }
}
