//! The fog ML server (Fig. 3, right): low-latency executor, model cache,
//! crop-classification pipeline with dynamic batching, and the fallback
//! detector that keeps service alive through cloud outages (Fig. 15).

pub mod cache;

use anyhow::{bail, Result};

use crate::cloud::HeadsOwned;
use crate::interchange::Tensor;
use crate::runtime::InferenceHandle;
use crate::serving::batcher::BatchPlanner;
use crate::sim::device::{DeviceProfile, FOG};

pub use cache::{FrameCache, FrameKey, ModelCache};

/// Decoded high-quality frames a shard keeps resident ([`FrameCache`]
/// capacity) — comfortably above the 15 frames of one chunk, so a whole
/// chunk's decode demands dedup to one render per frame.
pub const FRAME_CACHE_FRAMES: usize = 32;

/// One classified crop.
#[derive(Debug, Clone, Copy)]
pub struct CropResult {
    pub class: usize,
    /// One-vs-all probability of the winning class.
    pub prob: f64,
}

pub struct FogNode {
    handle: InferenceHandle,
    pub device: DeviceProfile,
    pub cache: ModelCache,
    /// Decoded-frame memo serving the region-crop, fallback-detect and
    /// round-2 decode demands (the paper's "fog caches the high-quality
    /// frame" protocol made literal).
    pub frames: FrameCache,
    /// Current classifier last layer `[H+1, K]` — swapped by the IL loop.
    w_last: Tensor,
    pub w_last_version: u64,
    gpu_free: f64,
    planner: BatchPlanner,
    feat_dim: usize,
    num_classes: usize,
    cls_feat: usize,
}

impl FogNode {
    pub fn new(
        handle: InferenceHandle,
        w_last0: Tensor,
        feat_dim: usize,
        num_classes: usize,
    ) -> Self {
        let cls_feat = w_last0.dims[0];
        FogNode {
            handle,
            device: FOG,
            cache: ModelCache::new(4),
            frames: FrameCache::new(FRAME_CACHE_FRAMES),
            w_last: w_last0,
            w_last_version: 0,
            gpu_free: 0.0,
            planner: BatchPlanner::new(vec![1, 4, 16]),
            feat_dim,
            num_classes,
            cls_feat,
        }
    }

    /// Swap in an updated last layer (the paper's "almost negligible
    /// overhead" model update: no recompilation, just new weights).
    pub fn set_last_layer(&mut self, w: Tensor) {
        assert_eq!(w.dims, self.w_last.dims);
        self.w_last = w;
        self.w_last_version += 1;
    }

    pub fn last_layer(&self) -> &Tensor {
        &self.w_last
    }

    fn schedule(&mut self, arrival: f64, dur: f64) -> (f64, f64) {
        let start = arrival.max(self.gpu_free);
        let done = start + dur;
        self.gpu_free = done;
        (start, done)
    }

    /// Seconds of queued GPU work still ahead of virtual time `now` — the
    /// per-shard backlog signal the scheduler's routing policy and the
    /// provisioner consume ([`crate::serverless::scheduler`]).
    pub fn backlog_s(&self, now: f64) -> f64 {
        (self.gpu_free - now).max(0.0)
    }

    /// Earliest virtual time this shard's GPU is free.
    pub fn earliest_free(&self) -> f64 {
        self.gpu_free
    }

    /// Quality control for a chunk at the fog (decode + re-encode), the
    /// step the paper moves off the weak client. Returns completion time.
    pub fn quality_control(&mut self, frames: usize, arrival: f64) -> f64 {
        let (_, done) = self.schedule(arrival, self.device.quality_control_s(frames));
        done
    }

    /// Classify region crops (each a `[D]` feature) with dynamic batching.
    /// Returns per-crop results, the feature vectors (for the HITL data
    /// collector), and the completion time.
    pub fn classify_crops(
        &mut self,
        crops: &[Vec<f32>],
        arrival: f64,
    ) -> Result<(Vec<CropResult>, Vec<Vec<f32>>, f64)> {
        if crops.is_empty() {
            return Ok((Vec::new(), Vec::new(), arrival));
        }
        let d = self.feat_dim;
        let k = self.num_classes;
        let plan = self.planner.plan(crops.len());
        let mut results = Vec::with_capacity(crops.len());
        let mut feats = Vec::with_capacity(crops.len());
        let mut done = arrival;
        let mut offset = 0;
        for b in plan {
            let take = b.min(crops.len() - offset);
            let mut data = vec![0.0f32; b * d];
            for i in 0..take {
                assert_eq!(crops[offset + i].len(), d);
                data[i * d..(i + 1) * d].copy_from_slice(&crops[offset + i]);
            }
            let input = Tensor::new(vec![b, d], data)?;
            let out = self
                .handle
                .infer(&format!("classifier_b{b}"), vec![input, self.w_last.clone()])?;
            // outputs: prob [b, K], feats [b, H+1]
            for i in 0..take {
                let row = &out[0].data[i * k..(i + 1) * k];
                let (mut best, mut best_p) = (0usize, f32::MIN);
                for (j, &p) in row.iter().enumerate() {
                    if p > best_p {
                        best = j;
                        best_p = p;
                    }
                }
                results.push(CropResult { class: best, prob: best_p as f64 });
                feats.push(out[1].data[i * self.cls_feat..(i + 1) * self.cls_feat].to_vec());
            }
            let (_, d_t) = self.schedule(arrival, self.device.batched(self.device.classify_s, b));
            done = done.max(d_t);
            offset += take;
        }
        Ok((results, feats, done))
    }

    /// Fallback detection with the lite model (cloud outage, Fig. 15).
    /// Frames are `[A, D]` tensors of the *high-quality* cached stream —
    /// owned, borrowed or `Arc`-shared out of the [`FrameCache`], hence
    /// the `Borrow` bound.
    pub fn fallback_detect<T: std::borrow::Borrow<Tensor>>(
        &mut self,
        frames: &[T],
        arrival: f64,
        grid: usize,
    ) -> Result<(Vec<HeadsOwned>, f64)> {
        if frames.is_empty() {
            bail!("empty chunk");
        }
        let a = grid * grid;
        let d = self.feat_dim;
        let k = self.num_classes;
        let plan = self.planner.plan(frames.len());
        let mut heads = Vec::with_capacity(frames.len());
        let mut done = arrival;
        let mut offset = 0;
        for b in plan {
            let take = b.min(frames.len() - offset);
            let mut data = vec![0.0f32; b * a * d];
            for i in 0..take {
                data[i * a * d..(i + 1) * a * d].copy_from_slice(&frames[offset + i].borrow().data);
            }
            let input = Tensor::new(vec![b, a, d], data)?;
            let out = self.handle.infer(&format!("detector_lite_b{b}"), vec![input])?;
            for i in 0..take {
                heads.push(HeadsOwned {
                    loc: out[0].data[i * a..(i + 1) * a].to_vec(),
                    cls: out[1].data[i * a * k..(i + 1) * a * k].to_vec(),
                    energy: out[2].data[i * a..(i + 1) * a].to_vec(),
                    grid,
                    num_classes: k,
                });
            }
            let (_, d_t) =
                self.schedule(arrival, self.device.batched(self.device.detect_lite_s, b));
            done = done.max(d_t);
            offset += take;
        }
        Ok((heads, done))
    }

    pub fn padding_frac(&self) -> f64 {
        self.planner.padding_frac()
    }
}

/// The generic-pool view of a fog shard
/// ([`crate::serverless::pool::TierPool`]): queue state only — the fog
/// tier bills nothing, so retirement has no carry-over, and its ops have
/// no co-located contention, so the default cost projection applies.
impl crate::serverless::pool::PoolWorker for FogNode {
    fn backlog_s(&self, now: f64) -> f64 {
        FogNode::backlog_s(self, now)
    }

    fn earliest_free(&self) -> f64 {
        FogNode::earliest_free(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InferenceService;
    use crate::sim::params::SimParams;
    use crate::sim::video::{render_crop, render_frame, FrameTruth, Quality, Scene, SceneConfig};

    fn fog_and_scene() -> (InferenceService, std::sync::Arc<SimParams>, FrameTruth) {
        let svc = InferenceService::start().unwrap();
        let p = SimParams::load().unwrap();
        let mut scene = Scene::new(SceneConfig {
            grid: p.grid,
            num_classes: p.num_classes,
            density: 4.0,
            speed: 0.4,
            size_range: (1.0, 2.0),
            class_skew: 0.5,
            seed: 9,
        });
        let truth = scene.step();
        (svc, p, truth)
    }

    #[test]
    fn classifies_high_quality_crops_correctly() {
        let (svc, p, truth) = fog_and_scene();
        let mut fog = FogNode::new(svc.handle(), p.cls_last0.clone(), p.feat_dim, p.num_classes);
        let crops: Vec<Vec<f32>> = truth
            .objects
            .iter()
            .map(|o| render_crop(o, Quality::ORIGINAL, 0.0, &p))
            .collect();
        let (results, feats, done) = fog.classify_crops(&crops, 1.0).unwrap();
        assert_eq!(results.len(), truth.objects.len());
        assert_eq!(feats[0].len(), p.cls_feat);
        assert!(done > 1.0);
        let correct = results
            .iter()
            .zip(&truth.objects)
            .filter(|(r, o)| r.class == o.gt.class)
            .count();
        assert!(correct as f64 / results.len() as f64 > 0.8, "{correct}/{} correct", results.len());
    }

    #[test]
    fn last_layer_swap_changes_predictions() {
        let (svc, p, truth) = fog_and_scene();
        let mut fog = FogNode::new(svc.handle(), p.cls_last0.clone(), p.feat_dim, p.num_classes);
        let crop = vec![render_crop(&truth.objects[0], Quality::ORIGINAL, 0.0, &p)];
        let (before, _, _) = fog.classify_crops(&crop, 0.0).unwrap();
        let zero = Tensor::zeros(p.cls_last0.dims.clone());
        fog.set_last_layer(zero);
        assert_eq!(fog.w_last_version, 1);
        let (after, _, _) = fog.classify_crops(&crop, 0.0).unwrap();
        // zero weights → all probs 0.5 → prediction degenerates
        assert!((after[0].prob - 0.5).abs() < 1e-4);
        assert!(before[0].prob > after[0].prob);
    }

    #[test]
    fn empty_crop_list_is_noop() {
        let (svc, p, _) = fog_and_scene();
        let mut fog = FogNode::new(svc.handle(), p.cls_last0.clone(), p.feat_dim, p.num_classes);
        let (r, f, done) = fog.classify_crops(&[], 3.0).unwrap();
        assert!(r.is_empty() && f.is_empty());
        assert_eq!(done, 3.0);
    }

    #[test]
    fn fallback_detector_localizes_on_high_quality() {
        let (svc, p, truth) = fog_and_scene();
        let mut fog = FogNode::new(svc.handle(), p.cls_last0.clone(), p.feat_dim, p.num_classes);
        let frame = render_frame(&truth, Quality::ORIGINAL, 0.0, &p);
        let (heads, done) = fog.fallback_detect(&[frame], 0.0, p.grid).unwrap();
        assert_eq!(heads.len(), 1);
        assert!(done > 0.0);
        let max_loc = heads[0].loc.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max_loc > 0.5, "lite detector found nothing");
    }

    #[test]
    fn quality_control_occupies_the_fog() {
        let (svc, p, _) = fog_and_scene();
        let mut fog = FogNode::new(svc.handle(), p.cls_last0.clone(), p.feat_dim, p.num_classes);
        let d1 = fog.quality_control(15, 0.0);
        let d2 = fog.quality_control(15, 0.0); // queues behind the first
        assert!(d2 > d1);
        assert!(d1 < 0.5, "fog QC must be fast (Fig. 4a): {d1}");
    }
}
