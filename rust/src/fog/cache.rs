//! The fog caches (Fig. 3): the *model* cache stores models dispatched
//! from the cloud, LRU-evicted under a capacity budget, with the IL loop
//! refreshing entries "periodically" by bumping their version — and the
//! *frame* cache memoizes decoded high-quality frames so the render-once
//! protocol (the cloud only ever sees low-quality video plus region
//! coordinates) costs one render per frame instead of one per demand.
//! Both report hit rates through [`GlobalMonitor`] via
//! [`FogShardPool::observe`].
//!
//! [`GlobalMonitor`]: crate::serverless::monitor::GlobalMonitor
//! [`FogShardPool::observe`]: crate::serverless::scheduler::FogShardPool::observe

use crate::interchange::Tensor;
use crate::sim::video::{FrameTruth, Quality};
use std::collections::VecDeque;
use std::sync::Arc;

/// An entry in the fog cache.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CachedModel {
    pub name: String,
    pub version: u64,
}

/// LRU cache of model names (compiled executables live in the shared PJRT
/// engine; this tracks *which* models the fog is allowed to serve — a cache
/// miss means a dispatch round-trip to the cloud zoo).
#[derive(Debug)]
pub struct ModelCache {
    capacity: usize,
    // front = most recent
    entries: VecDeque<CachedModel>,
    pub hits: u64,
    pub misses: u64,
}

impl ModelCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ModelCache { capacity, entries: VecDeque::new(), hits: 0, misses: 0 }
    }

    /// Install (dispatch) a model, evicting the LRU entry if full.
    /// Returns the evicted model, if any.
    pub fn install(&mut self, name: &str, version: u64) -> Option<CachedModel> {
        self.entries.retain(|e| e.name != name);
        self.entries.push_front(CachedModel { name: name.to_string(), version });
        if self.entries.len() > self.capacity { self.entries.pop_back() } else { None }
    }

    /// Touch a model for serving. Hit → bump recency; miss → recorded.
    pub fn lookup(&mut self, name: &str) -> Option<CachedModel> {
        if let Some(pos) = self.entries.iter().position(|e| e.name == name) {
            let entry = self.entries.remove(pos).unwrap();
            self.entries.push_front(entry.clone());
            self.hits += 1;
            Some(entry)
        } else {
            self.misses += 1;
            None
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Refresh a cached model's version in place (the IL update path).
    pub fn refresh(&mut self, name: &str, version: u64) -> bool {
        for e in self.entries.iter_mut() {
            if e.name == name {
                e.version = version;
                return true;
            }
        }
        false
    }

    /// Lifetime hit rate, `None` before the first lookup.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        (total > 0).then(|| self.hits as f64 / total as f64)
    }
}

/// Identity of one decoded frame. `clutter_seed` already folds the video
/// seed and the frame index together (see
/// [`FrameTruth`](crate::sim::video::FrameTruth)); `frame_idx` rides along
/// so a (vanishingly unlikely) cross-video seed collision still cannot
/// alias. Quality and drift enter as exact bit patterns — renders are pure
/// in `(truth, quality, phi)`, so bit-equal keys imply byte-equal frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameKey {
    clutter_seed: u64,
    frame_idx: u64,
    r_bits: u64,
    qp_bits: u64,
    phi_bits: u64,
}

impl FrameKey {
    pub fn new(truth: &FrameTruth, q: Quality, phi: f64) -> Self {
        FrameKey {
            clutter_seed: truth.clutter_seed,
            frame_idx: truth.frame_idx,
            r_bits: q.r.to_bits(),
            qp_bits: q.qp.to_bits(),
            phi_bits: phi.to_bits(),
        }
    }
}

/// Capacity-bounded LRU memo of rendered (decoded) frames.
///
/// Because renders are pure functions of the key, a cached frame is
/// byte-identical to a fresh render — the cache can only move wall-clock
/// time, never a simulated byte. Hit/miss accounting is resolved on the
/// single-threaded event loop in demand order (see
/// [`FrameCache::plan`]), so the ledger is also thread-count invariant.
/// Entries are `Arc`-shared: eviction can never invalidate a frame a
/// consumer still holds.
///
/// `capacity == 0` is the metering-only mode the `--no-frame-cache` run
/// uses for its baseline: every demand is a recorded miss and nothing is
/// ever resident.
#[derive(Debug, Default)]
pub struct FrameCache {
    capacity: usize,
    // front = most recent
    entries: VecDeque<(FrameKey, Arc<Tensor>)>,
    pub hits: u64,
    pub misses: u64,
}

impl FrameCache {
    pub fn new(capacity: usize) -> Self {
        FrameCache { capacity, entries: VecDeque::new(), hits: 0, misses: 0 }
    }

    /// Bump a resident key to most-recent. No accounting.
    fn touch(&mut self, key: &FrameKey) -> bool {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            let entry = self.entries.remove(pos).unwrap();
            self.entries.push_front(entry);
            true
        } else {
            false
        }
    }

    /// Resident frame for `key`, if any. No accounting, no recency bump —
    /// the retrieval half of a [`FrameCache::plan`] round, which already
    /// did both.
    pub fn get(&self, key: &FrameKey) -> Option<Arc<Tensor>> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, t)| Arc::clone(t))
    }

    /// Resolve one batch of decode demands, in demand order, against the
    /// resident set plus the demands already planned within this batch
    /// (their renders land before any decoded frame is consumed). Returns
    /// the indices of demands that must actually render, in first-demand
    /// order; every demand is counted as a hit or a miss. With
    /// `capacity == 0` nothing is resident or planned, so every demand
    /// renders. Callers keep a batch within capacity (one chunk's frames
    /// against [`FRAME_CACHE_FRAMES`](crate::fog::FRAME_CACHE_FRAMES)).
    pub fn plan(&mut self, keys: &[FrameKey]) -> Vec<usize> {
        let mut to_render: Vec<usize> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            let pending =
                self.capacity > 0 && to_render.iter().any(|&j| keys[j] == *key);
            if self.touch(key) || pending {
                self.hits += 1;
            } else {
                self.misses += 1;
                to_render.push(i);
            }
        }
        to_render
    }

    /// Cache-off accounting for a batch of `n` demands: every demand is a
    /// recorded miss and every demand renders.
    pub fn plan_bypass(&mut self, n: usize) -> Vec<usize> {
        self.misses += n as u64;
        (0..n).collect()
    }

    /// Install a rendered frame, evicting the LRU entry when full.
    /// Returns the evicted key, if any. A no-op at `capacity == 0`.
    pub fn insert(&mut self, key: FrameKey, frame: Arc<Tensor>) -> Option<FrameKey> {
        if self.capacity == 0 {
            return None;
        }
        self.entries.retain(|(k, _)| *k != key);
        self.entries.push_front((key, frame));
        if self.entries.len() > self.capacity {
            self.entries.pop_back().map(|(k, _)| k)
        } else {
            None
        }
    }

    /// Single-demand path (the sequential DDS baseline): hit returns the
    /// resident frame, miss renders and installs it. Accounting included.
    pub fn fetch(
        &mut self,
        truth: &FrameTruth,
        q: Quality,
        phi: f64,
        render: impl FnOnce() -> Tensor,
    ) -> Arc<Tensor> {
        let key = FrameKey::new(truth, q, phi);
        if self.touch(&key) {
            self.hits += 1;
            return self.get(&key).unwrap();
        }
        self.misses += 1;
        let frame = Arc::new(render());
        self.insert(key, Arc::clone(&frame));
        frame
    }

    pub fn contains(&self, key: &FrameKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lifetime hit rate, `None` before the first demand.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        (total > 0).then(|| self.hits as f64 / total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_lookup() {
        let mut c = ModelCache::new(2);
        c.install("cls", 1);
        assert!(c.lookup("cls").is_some());
        assert_eq!(c.hits, 1);
        assert!(c.lookup("missing").is_none());
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = ModelCache::new(2);
        c.install("a", 1);
        c.install("b", 1);
        c.lookup("a"); // a is now most-recent
        let evicted = c.install("c", 1).unwrap();
        assert_eq!(evicted.name, "b");
        assert!(c.contains("a") && c.contains("c"));
    }

    #[test]
    fn reinstall_moves_to_front_without_growth() {
        let mut c = ModelCache::new(2);
        c.install("a", 1);
        c.install("b", 1);
        c.install("a", 2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup("a").unwrap().version, 2);
    }

    #[test]
    fn refresh_bumps_version() {
        let mut c = ModelCache::new(2);
        c.install("cls", 1);
        assert!(c.refresh("cls", 5));
        assert_eq!(c.lookup("cls").unwrap().version, 5);
        assert!(!c.refresh("ghost", 1));
    }

    #[test]
    fn model_cache_hit_rate_tracks_lookups() {
        let mut c = ModelCache::new(2);
        assert_eq!(c.hit_rate(), None);
        c.install("cls", 1);
        c.lookup("cls");
        c.lookup("ghost");
        c.lookup("cls");
        assert_eq!(c.hit_rate(), Some(2.0 / 3.0));
    }

    // -- FrameCache ---------------------------------------------------

    fn truth(frame_idx: u64) -> crate::sim::video::FrameTruth {
        crate::sim::video::FrameTruth {
            frame_idx,
            clutter_seed: 0xABCD ^ frame_idx.wrapping_mul(0x9E3779B97F4A7C15),
            objects: Vec::new(),
        }
    }

    fn frame(tag: f32) -> Arc<Tensor> {
        Arc::new(Tensor { dims: vec![1, 1], data: vec![tag] })
    }

    #[test]
    fn frame_plan_accounts_every_demand_and_renders_once_per_frame() {
        let mut c = FrameCache::new(4);
        let (t0, t1) = (truth(0), truth(1));
        let q = Quality::ORIGINAL;
        // region demands: frame 0 twice, frame 1 once — one render each
        let keys = vec![
            FrameKey::new(&t0, q, 0.0),
            FrameKey::new(&t0, q, 0.0),
            FrameKey::new(&t1, q, 0.0),
        ];
        let miss = c.plan(&keys);
        assert_eq!(miss, vec![0, 2], "first demand per distinct frame renders");
        assert_eq!((c.hits, c.misses), (1, 2));
        c.insert(keys[0], frame(0.0));
        c.insert(keys[2], frame(1.0));
        // the same chunk re-demanded is all hits
        let miss = c.plan(&keys);
        assert!(miss.is_empty());
        assert_eq!((c.hits, c.misses), (4, 2));
        assert_eq!(c.hit_rate(), Some(4.0 / 6.0));
        // a different quality is a different frame
        let other = vec![FrameKey::new(&t0, Quality::LOW, 0.0)];
        assert_eq!(c.plan(&other), vec![0]);
        // ... and so is a different drift phase
        let drifted = vec![FrameKey::new(&t0, q, 0.25)];
        assert_eq!(c.plan(&drifted), vec![0]);
    }

    #[test]
    fn frame_cache_holds_the_lru_bound_and_evicts_deterministically() {
        let mut c = FrameCache::new(2);
        let q = Quality::ORIGINAL;
        let keys: Vec<FrameKey> =
            (0..3).map(|i| FrameKey::new(&truth(i), q, 0.0)).collect();
        assert!(c.insert(keys[0], frame(0.0)).is_none());
        assert!(c.insert(keys[1], frame(1.0)).is_none());
        // touch 0 → 1 becomes LRU → inserting 2 evicts exactly 1
        assert!(c.plan(&keys[0..1]).is_empty());
        assert_eq!(c.insert(keys[2], frame(2.0)), Some(keys[1]));
        assert_eq!(c.len(), 2);
        assert!(c.contains(&keys[0]) && c.contains(&keys[2]) && !c.contains(&keys[1]));
        // eviction is a pure function of the demand sequence: replaying
        // the same ops on a fresh cache evicts the same key
        let mut d = FrameCache::new(2);
        d.insert(keys[0], frame(0.0));
        d.insert(keys[1], frame(1.0));
        d.plan(&keys[0..1]);
        assert_eq!(d.insert(keys[2], frame(2.0)), Some(keys[1]));
        // an evicted entry stays alive for holders of the Arc
        let held = c.get(&keys[0]).unwrap();
        c.insert(keys[1], frame(1.0));
        c.insert(FrameKey::new(&truth(9), q, 0.0), frame(9.0));
        assert_eq!(held.data, vec![0.0]);
    }

    #[test]
    fn zero_capacity_meters_without_storing() {
        let mut c = FrameCache::new(0);
        let q = Quality::ORIGINAL;
        let keys = vec![FrameKey::new(&truth(0), q, 0.0), FrameKey::new(&truth(0), q, 0.0)];
        // duplicate demands both render: nothing is resident or planned
        assert_eq!(c.plan(&keys), vec![0, 1]);
        assert!(c.insert(keys[0], frame(0.0)).is_none());
        assert!(c.is_empty());
        assert_eq!((c.hits, c.misses), (0, 2));
        assert_eq!(c.plan_bypass(3), vec![0, 1, 2]);
        assert_eq!(c.misses, 5);
    }

    #[test]
    fn fetch_renders_once_and_serves_the_memo_after() {
        let mut c = FrameCache::new(2);
        let t = truth(4);
        let mut renders = 0u32;
        let a = c.fetch(&t, Quality::ORIGINAL, 0.1, || {
            renders += 1;
            Tensor { dims: vec![1, 1], data: vec![7.0] }
        });
        let b = c.fetch(&t, Quality::ORIGINAL, 0.1, || {
            renders += 1;
            Tensor { dims: vec![1, 1], data: vec![7.0] }
        });
        assert_eq!(renders, 1, "second fetch must be served from the memo");
        assert_eq!(a.data, b.data);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((c.hits, c.misses), (1, 1));
    }
}
