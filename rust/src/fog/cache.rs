//! The fog model cache (Fig. 3): stores models dispatched from the cloud,
//! LRU-evicted under a capacity budget; the IL loop refreshes entries
//! "periodically" by bumping their version.

use std::collections::VecDeque;

/// An entry in the fog cache.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CachedModel {
    pub name: String,
    pub version: u64,
}

/// LRU cache of model names (compiled executables live in the shared PJRT
/// engine; this tracks *which* models the fog is allowed to serve — a cache
/// miss means a dispatch round-trip to the cloud zoo).
#[derive(Debug)]
pub struct ModelCache {
    capacity: usize,
    // front = most recent
    entries: VecDeque<CachedModel>,
    pub hits: u64,
    pub misses: u64,
}

impl ModelCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ModelCache { capacity, entries: VecDeque::new(), hits: 0, misses: 0 }
    }

    /// Install (dispatch) a model, evicting the LRU entry if full.
    /// Returns the evicted model, if any.
    pub fn install(&mut self, name: &str, version: u64) -> Option<CachedModel> {
        self.entries.retain(|e| e.name != name);
        self.entries.push_front(CachedModel { name: name.to_string(), version });
        if self.entries.len() > self.capacity { self.entries.pop_back() } else { None }
    }

    /// Touch a model for serving. Hit → bump recency; miss → recorded.
    pub fn lookup(&mut self, name: &str) -> Option<CachedModel> {
        if let Some(pos) = self.entries.iter().position(|e| e.name == name) {
            let entry = self.entries.remove(pos).unwrap();
            self.entries.push_front(entry.clone());
            self.hits += 1;
            Some(entry)
        } else {
            self.misses += 1;
            None
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Refresh a cached model's version in place (the IL update path).
    pub fn refresh(&mut self, name: &str, version: u64) -> bool {
        for e in self.entries.iter_mut() {
            if e.name == name {
                e.version = version;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_lookup() {
        let mut c = ModelCache::new(2);
        c.install("cls", 1);
        assert!(c.lookup("cls").is_some());
        assert_eq!(c.hits, 1);
        assert!(c.lookup("missing").is_none());
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = ModelCache::new(2);
        c.install("a", 1);
        c.install("b", 1);
        c.lookup("a"); // a is now most-recent
        let evicted = c.install("c", 1).unwrap();
        assert_eq!(evicted.name, "b");
        assert!(c.contains("a") && c.contains("c"));
    }

    #[test]
    fn reinstall_moves_to_front_without_growth() {
        let mut c = ModelCache::new(2);
        c.install("a", 1);
        c.install("b", 1);
        c.install("a", 2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup("a").unwrap().version, 2);
    }

    #[test]
    fn refresh_bumps_version() {
        let mut c = ModelCache::new(2);
        c.install("cls", 1);
        assert!(c.refresh("cls", 5));
        assert_eq!(c.lookup("cls").unwrap().version, 5);
        assert!(!c.refresh("ghost", 1));
    }
}
