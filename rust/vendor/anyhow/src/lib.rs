//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the repository
//! vendors the exact `anyhow` surface it uses: [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` macros. Errors are flattened to
//! a single message string (no backtraces, no source chains) — every caller
//! in this codebase formats errors for humans, so nothing is lost.
//!
//! The `Context` / `From` impl structure mirrors the real crate's coherence
//! trick: a helper trait implemented for both `Error` itself and every
//! `std::error::Error`, which is accepted because `Error` deliberately does
//! NOT implement `std::error::Error`.

use std::fmt;

/// A flattened error message, API-compatible with `anyhow::Error` for the
/// operations this repository performs (`Display`, `Debug`, `to_string`,
/// `{e:#}` formatting, `?` conversions from std errors).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — plain `Result` defaulting the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt;

    /// Sealed helper: anything that can absorb a context message and become
    /// an [`Error`]. Implemented for `Error` and for std errors; the two
    /// impls do not overlap because `Error: !std::error::Error`.
    pub trait ContextError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl ContextError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.wrap(context)
        }
    }

    impl<E> ContextError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::msg(format!("{context}: {self}"))
        }
    }
}

/// Attach human context to an error while propagating it.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::ContextError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is not satisfied
/// (`assert!`-shaped [`bail!`]).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context_compose() {
        let base: Result<()> = Err(anyhow!("base {}", 7));
        let err = base.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer: base 7");
        let with: Result<(), std::num::ParseIntError> = "x".parse::<i32>().map(|_| ());
        let err = with.with_context(|| "parsing x").unwrap_err();
        assert!(err.to_string().starts_with("parsing x: "));
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {flag}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged true");
    }

    #[test]
    fn ensure_guards_conditions() {
        fn f(n: u32) -> Result<u32> {
            ensure!(n < 10, "too big: {n}");
            ensure!(n != 7);
            Ok(n)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert!(f(7).unwrap_err().to_string().contains("n != 7"));
    }
}
