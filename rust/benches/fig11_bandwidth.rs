//! Bench: Fig. 11 — latency stability across WAN bandwidths {10,15,20} Mbps.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::{figures, Harness, RunConfig, SystemKind};
use vpaas::sim::video::datasets;

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    println!("{}", figures::fig11(&h, bench_scale(), &cfg).unwrap());
    // robustness claim: vpaas p50 at 10 Mbps within 2x of p50 at 20 Mbps
    let ds = datasets::traffic(bench_scale());
    let p50 = |wan: f64| {
        let m = h
            .run(SystemKind::Vpaas, &ds, &RunConfig { wan_mbps: wan, ..cfg.clone() })
            .unwrap();
        m.latency.summary().p50
    };
    let (slow, fast) = (p50(10.0), p50(20.0));
    assert!(slow < 2.0 * fast, "vpaas not robust to bandwidth: {slow} vs {fast}");
    bench("fig11/vpaas_at_10mbps", 3, || {
        p50(10.0);
    });
}
