//! Shared support for the custom bench harness (criterion is not vendored
//! in this environment — see DESIGN.md §Installed-tooling substitutions).
//!
//! Each bench binary regenerates its paper figure/table (correctness
//! artifact) and then times the figure's core loop with warmup + repeated
//! iterations, reporting mean/p50/p99 wall time.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub samples_ms: Vec<f64>,
}

impl BenchResult {
    fn pct(&self, p: f64) -> f64 {
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0 * (s.len() - 1) as f64).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn report(&self) {
        let mean = self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64;
        println!(
            "bench {:<28} iters={:<3} mean={:>9.2}ms p50={:>9.2}ms p99={:>9.2}ms",
            self.name,
            self.iters,
            mean,
            self.pct(50.0),
            self.pct(99.0)
        );
    }
}

/// Time `f` with one warmup call and `iters` measured calls.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let r = BenchResult { name: name.to_string(), iters, samples_ms: samples };
    r.report();
    r
}

/// Scale for bench-time dataset runs (keeps `cargo bench` minutes-scale).
pub fn bench_scale() -> f64 {
    std::env::var("VPAAS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02)
}
