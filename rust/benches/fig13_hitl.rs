//! Bench: Fig. 13 — HITL budget vs accuracy (13a) and training overhead (13b).
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::{figures, Harness, RunConfig, SystemKind};
use vpaas::sim::video::datasets;

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig::default();
    println!("{}", figures::fig13a(&h, bench_scale(), &cfg).unwrap());
    println!("{}", figures::fig13b(&h, bench_scale(), &cfg).unwrap());
    // the headline: IL must beat the no-HITL ablation under drift
    let ds = datasets::traffic(bench_scale());
    let drift =
        RunConfig { drift: true, drift_scale: 12.0, golden: false, hitl_budget: 0.4, ..cfg };
    let with = h.run(SystemKind::Vpaas, &ds, &drift).unwrap();
    let without = h.run(SystemKind::VpaasNoHitl, &ds, &drift).unwrap();
    assert!(
        with.f1_true.f1() >= without.f1_true.f1(),
        "HITL made accuracy worse: {} vs {}",
        with.f1_true.f1(),
        without.f1_true.f1()
    );
    bench("fig13/vpaas_hitl_run", 3, || {
        h.run(SystemKind::Vpaas, &ds, &drift).unwrap();
    });
}
