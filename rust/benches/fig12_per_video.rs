//! Bench: Fig. 12 — per-video bandwidth (VPaaS normalized to DDS).
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    println!("{}", figures::fig12(&h, bench_scale(), &cfg).unwrap());
    bench("fig12/regenerate", 3, || {
        figures::fig12(&h, bench_scale(), &cfg).unwrap();
    });
}
