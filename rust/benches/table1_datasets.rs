//! Bench: Table I — dataset generation throughput + spec regeneration.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::figures;
use vpaas::sim::params::SimParams;
use vpaas::sim::video::datasets;

fn main() {
    println!("{}", figures::table1(1.0));
    let p = SimParams::load().expect("run `make artifacts`");
    bench("table1/generate_drone_chunks", 5, || {
        let mut videos = datasets::drone(bench_scale()).make_videos(&p);
        let mut total = 0usize;
        for v in videos.iter_mut().take(4) {
            while let Some(c) = v.next_chunk() {
                total += c.total_objects();
            }
        }
        assert!(total > 0);
    });
}
