//! Bench: Fig. 9 — normalized bandwidth + F1, all systems x all datasets.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::{figures, Harness, RunConfig, SystemKind};
use vpaas::sim::video::datasets;

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig::default();
    let runs = figures::macro_runs(&h, bench_scale(), &cfg).unwrap();
    println!("{}", figures::fig9(&runs));
    // sanity: the paper's ordering must hold on every dataset
    for (ds, metrics) in &runs {
        let f1 = |name: &str| metrics.iter().find(|m| m.system == name).unwrap().f1_true.f1();
        let bw = |name: &str| metrics.iter().find(|m| m.system == name).unwrap().bandwidth.bytes;
        assert!(bw("vpaas") < bw("mpeg") * 0.5, "{ds}: vpaas must save vs mpeg");
        assert!(bw("vpaas") <= bw("dds") * 1.001, "{ds}: vpaas <= dds bandwidth");
        assert!(f1("vpaas") > f1("glimpse") - 0.02, "{ds}: vpaas vs glimpse accuracy");
    }
    let ds = datasets::drone(bench_scale());
    let quick = RunConfig { golden: false, ..RunConfig::default() };
    bench("fig9/vpaas_drone_end_to_end", 5, || {
        h.run(SystemKind::Vpaas, &ds, &quick).unwrap();
    });
}
