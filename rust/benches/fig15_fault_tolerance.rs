//! Bench: Fig. 15 — cloud outage at t=25 s; fog fallback keeps serving.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    let (text, trace) = figures::fig15(&h, &cfg).unwrap();
    println!("{text}");
    assert!(trace.rows.iter().any(|r| r.3), "no fallback window");
    assert!(!trace.rows.last().unwrap().3, "no recovery");
    bench("fig15/outage_timeline", 3, || {
        figures::fig15(&h, &cfg).unwrap();
    });
}
