//! Bench: Fig. 16 — autoscaling under a camera-fleet ramp, plus the
//! multi-fog shard sweep (throughput at shard counts {1, 2, 4, 8}).
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    let text = figures::fig16(&h, &cfg).unwrap();
    println!("{text}");
    assert!(text.contains("gpus"), "missing provisioning history");
    let sweep = figures::fig16_shard_sweep(&h, &cfg).unwrap();
    println!("{sweep}");
    assert!(sweep.contains("throughput"), "missing shard-sweep throughput");
    bench("fig16/fleet_ramp", 3, || {
        figures::fig16(&h, &cfg).unwrap();
    });
    bench("fig16/shard_sweep", 3, || {
        figures::fig16_shard_sweep(&h, &cfg).unwrap();
    });
}
