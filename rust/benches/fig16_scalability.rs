//! Bench: Fig. 16 — autoscaling under a camera-fleet ramp, the multi-fog
//! shard sweep (throughput at shard counts {1, 2, 4, 8}), the event-driven
//! vs sequential dispatch comparison (`BENCH_overlap.json`), the
//! run-scoped streaming vs wave-barrier vs sequential sweep across
//! workload profiles (`BENCH_stream.json`), the cloud GPU pool sweep at
//! worker counts {1, 2, 4, 8} (`BENCH_gpu.json`), the worker-thread
//! wall-clock sweep (`BENCH_par.json`), and the render-once hot-path
//! sweep (`BENCH_hotpath.json`, frame cache on/off × thread counts —
//! these last two measure host time rather than the virtual clock) — the
//! JSON artifacts are uploaded by CI so the perf trajectory is visible
//! per PR. The virtual-time sweeps run as declarative studies
//! (`vpaas::study`) and the JSON encoders live in `pipeline::figures`,
//! shared with the schema tests.
//!
//! Set `VPAAS_BENCH_SMOKE=1` for the reduced CI configuration: fewer
//! cameras, a shorter dataset, no repeated timing reps — the JSON
//! artifacts are still written.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};
use vpaas::serverless::app::bench_smoke;

fn main() {
    let smoke = bench_smoke();
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };

    if !smoke {
        let text = figures::fig16(&h, &cfg).unwrap();
        println!("{text}");
        assert!(text.contains("gpus"), "missing provisioning history");
        let sweep = figures::fig16_shard_sweep(&h, &cfg).unwrap();
        println!("{sweep}");
        assert!(sweep.contains("throughput"), "missing shard-sweep throughput");
    }

    // event-driven overlap vs the sequential state machine, as JSON; the
    // smoke configuration shrinks the camera fleet, dataset scale and
    // shard sweep so the per-PR job stays cheap
    let (cameras, scale) = if smoke { (4, 0.1) } else { (6, 0.2) };
    let shard_counts: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8] };
    let (overlap, rows) = figures::fig16_overlap(&h, &cfg, cameras, scale, shard_counts).unwrap();
    println!("{overlap}");
    let json = figures::overlap_json(cameras, &rows);
    std::fs::write("BENCH_overlap.json", &json).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json: {json}");
    // tiny tolerance: earliest-ready-first can, in principle, delay one
    // long-tailed chunk behind a quicker one on an unlucky seed
    for &(shards, event, seq) in &rows {
        assert!(
            event <= seq * 1.05 + 1e-6,
            "event dispatch slowed the fleet at {shards} shards: {event} vs {seq}"
        );
    }

    // run-scoped streaming vs wave-barrier vs sequential, per workload
    // profile (uniform / bursty / churn), as JSON
    let (stream_text, stream_rows) = figures::fig16_stream(&h, &cfg, cameras, scale).unwrap();
    println!("{stream_text}");
    let json = figures::stream_json(cameras, &stream_rows);
    std::fs::write("BENCH_stream.json", &json).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json: {json}");
    // makespan ordering: authoritative gating lives in the tier-1 tests
    // (rust/tests/streaming.rs) at a deliberately chosen configuration;
    // at the reduced smoke scale a miss is reported, not fatal, so the
    // per-PR artifact job cannot flake on an untuned workload size
    for r in &stream_rows {
        let stream_ok = r.streaming_s <= r.wave_s * 1.05 + 1e-6;
        let wave_ok = r.wave_s <= r.sequential_s * 1.05 + 1e-6;
        if smoke {
            if !stream_ok || !wave_ok {
                println!("WARN: makespan ordering violated at smoke scale: {r:?}");
            }
        } else {
            assert!(
                stream_ok,
                "streaming slowed the fleet on {}: {} vs wave {}",
                r.workload, r.streaming_s, r.wave_s
            );
            assert!(
                wave_ok,
                "wave dispatch slower than sequential on {}: {} vs {}",
                r.workload, r.wave_s, r.sequential_s
            );
        }
    }
    // cross-wave overlap must buy real makespan somewhere — at minimum on
    // a bursty profile, where admission piles waves back-to-back. At the
    // tiny smoke scale the waves may genuinely never overlap, so there the
    // miss is reported rather than fatal.
    let strict_win = stream_rows.iter().any(|r| r.streaming_s < r.wave_s);
    if smoke && !strict_win {
        println!("WARN: streaming never beat the wave barrier at smoke scale: {stream_rows:?}");
    } else {
        assert!(strict_win, "streaming never beat the wave barrier: {stream_rows:?}");
    }

    // cloud GPU pool sweep (the fig16 fleet story at worker granularity),
    // as JSON; smoke shrinks the fleet and drops the 8-worker point
    let (gpu_cams, gpu_scale) = if smoke { (8, 0.05) } else { (16, 0.1) };
    let gpu_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let (gpu_text, gpu_rows) =
        figures::fig16_gpu_sweep(&h, &cfg, gpu_cams, gpu_scale, gpu_counts).unwrap();
    println!("{gpu_text}");
    let json = figures::gpu_json(gpu_cams, &gpu_rows);
    std::fs::write("BENCH_gpu.json", &json).expect("write BENCH_gpu.json");
    println!("wrote BENCH_gpu.json: {json}");
    let m1 = gpu_rows.iter().find(|r| r.gpus == 1).expect("1-gpu row").makespan_s;
    let m4 = gpu_rows.iter().find(|r| r.gpus == 4).expect("4-gpu row").makespan_s;
    // more GPU workers must never slow the fleet (small routing tolerance)
    for r in &gpu_rows {
        let ok = r.makespan_s <= m1 * 1.02 + 1e-6;
        if smoke {
            if !ok {
                println!("WARN: {} GPUs slower than 1 at smoke scale: {gpu_rows:?}", r.gpus);
            }
        } else {
            assert!(ok, "{} GPUs slowed the fleet: {} vs {m1}", r.gpus, r.makespan_s);
        }
    }
    // ... and at full scale the pool must buy real makespan by 4 workers.
    // At the tiny smoke scale the GPU queue may never bind, so a miss is
    // reported rather than fatal there.
    if smoke {
        if m4 >= m1 {
            println!("WARN: 4-GPU makespan did not improve at smoke scale: {gpu_rows:?}");
        }
    } else {
        assert!(m4 < m1, "4-GPU pool never beat 1 GPU: {gpu_rows:?}");
    }

    // worker-thread wall-clock sweep: the one artifact timed on the host
    // clock. fig16_par_sweep itself asserts the determinism contract —
    // every thread count's content fingerprint is bit-identical — before
    // any timing is reported. Smoke shrinks the fleet and drops the
    // 8-thread point; wall-clock speedup assertions only run at the full
    // shape, where the workload is big enough to amortize thread startup.
    let (par_cams, par_scale) = if smoke { (8, 0.05) } else { (16, 0.1) };
    let par_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let (par_text, par_rows) =
        figures::fig16_par_sweep(&h, &cfg, par_cams, par_scale, par_counts).unwrap();
    println!("{par_text}");
    let json = figures::par_json(par_cams, &par_rows);
    std::fs::write("BENCH_par.json", &json).expect("write BENCH_par.json");
    println!("wrote BENCH_par.json: {json}");
    let w1 = par_rows.iter().find(|r| r.threads == 1).expect("1-thread row").wall_s;
    if smoke {
        if !par_rows.iter().any(|r| r.threads > 1 && r.wall_s < w1) {
            println!("WARN: no wall-clock win from threads at smoke scale: {par_rows:?}");
        }
    } else {
        // the tentpole claim: at the full bench shape every multi-thread
        // point is strictly faster than single-threaded on the wall clock
        for r in par_rows.iter().filter(|r| r.threads > 1) {
            assert!(
                r.wall_s < w1,
                "{} threads did not beat 1 thread on the wall clock: {} vs {w1}",
                r.threads,
                r.wall_s
            );
        }
    }

    // render-once hot path: frame cache on/off × worker threads, timed on
    // the host clock. fig16_hotpath itself asserts the determinism
    // contract (fingerprint + makespan bits identical at every cell, and
    // decode-demand volume invariant under the cache flag) before any
    // timing is reported. The cache-beats-baseline assertion only runs at
    // the full shape, where the decode volume is big enough to dominate
    // the memo's bookkeeping.
    let (hot_cams, hot_scale) = if smoke { (8, 0.05) } else { (16, 0.1) };
    let hot_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 4] };
    let (hot_text, hot_rows) =
        figures::fig16_hotpath(&h, &cfg, hot_cams, hot_scale, hot_counts).unwrap();
    println!("{hot_text}");
    let json = figures::hotpath_json(hot_cams, &hot_rows);
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json: {json}");
    for &threads in hot_counts {
        let cell = |cache: bool| {
            hot_rows
                .iter()
                .find(|r| r.threads == threads && r.frame_cache == cache)
                .expect("swept hotpath cell")
        };
        let (off, on) = (cell(false), cell(true));
        if smoke {
            if on.wall_s >= off.wall_s {
                println!(
                    "WARN: frame cache did not beat cache-off at smoke scale \
                     ({threads} threads): {} vs {}",
                    on.wall_s, off.wall_s
                );
            }
        } else {
            // the tentpole claim: rendering each frame once must strictly
            // beat per-region re-rendering at every swept thread count
            assert!(
                on.wall_s < off.wall_s,
                "frame cache did not beat cache-off at {threads} threads: {} vs {}",
                on.wall_s,
                off.wall_s
            );
        }
    }

    // SLO/cost frontier: freshness target × degrade ladder, as JSON;
    // smoke shrinks the fleet and the target grid. Note sub-capture-span
    // targets (< 7.5 s) refuse every chunk — they chart the refusal edge.
    let (slo_cams, slo_scale) = if smoke { (4, 0.05) } else { (6, 0.1) };
    let slo_points: &[f64] = if smoke {
        &[f64::INFINITY, 10_000.0, 800.0]
    } else {
        &[f64::INFINITY, 12_000.0, 10_000.0, 8_500.0, 800.0, 200.0]
    };
    let (slo_text, slo_rows) =
        figures::fig10_slo_frontier(&h, &cfg, slo_cams, slo_scale, slo_points).unwrap();
    println!("{slo_text}");
    let json = figures::slo_json(slo_cams, &slo_rows);
    std::fs::write("BENCH_slo.json", &json).expect("write BENCH_slo.json");
    println!("wrote BENCH_slo.json: {json}");
    // dominance checks on the frontier rows. Ladder: at every target and
    // batching mode the multi-rung ladder must not drop more chunks than
    // the single-step controller (it only ever adds feasible rungs above
    // the shared floor). Batching: with the SLO disabled the adaptive
    // planner must be inert (identical counters — asserted even at smoke
    // scale, it is a determinism property, not a tuning one), and across
    // the binding targets it must not drop more chunks in aggregate than
    // static full-wave batching; accuracy ordering is asserted in the
    // tier-1 frontier test at a tuned configuration, not at smoke scale
    let find = |slo: f64, ladder: bool, adaptive: bool| {
        slo_rows
            .iter()
            .find(|r| {
                r.slo_ms.to_bits() == slo.to_bits() && r.ladder == ladder && r.adaptive == adaptive
            })
            .expect("planned frontier row")
    };
    for &slo in slo_points {
        for adaptive in [false, true] {
            let on = find(slo, true, adaptive);
            let off = find(slo, false, adaptive);
            let ok = on.chunks_dropped <= off.chunks_dropped;
            if smoke {
                if !ok {
                    println!(
                        "WARN: ladder dropped more than single-step at smoke scale: {on:?} vs {off:?}"
                    );
                }
            } else {
                assert!(ok, "ladder dropped more chunks than single-step: {on:?} vs {off:?}");
            }
        }
        if !slo.is_finite() {
            for ladder in [true, false] {
                let ada = find(slo, ladder, true);
                let sta = find(slo, ladder, false);
                assert_eq!(
                    (ada.chunks, ada.chunks_dropped, ada.f1.to_bits()),
                    (sta.chunks, sta.chunks_dropped, sta.f1.to_bits()),
                    "adaptive batching moved an SLO-disabled run"
                );
            }
        }
    }
    let dropped = |adaptive: bool| -> u64 {
        slo_rows
            .iter()
            .filter(|r| r.adaptive == adaptive && r.slo_ms.is_finite())
            .map(|r| r.chunks_dropped)
            .sum()
    };
    let (ada_drops, sta_drops) = (dropped(true), dropped(false));
    if smoke {
        if ada_drops > sta_drops {
            println!(
                "WARN: adaptive batching dropped more than static at smoke scale: \
                 {ada_drops} vs {sta_drops}"
            );
        }
    } else {
        assert!(
            ada_drops <= sta_drops,
            "adaptive batching dropped more chunks overall: {ada_drops} vs {sta_drops}"
        );
    }

    if !smoke {
        bench("fig16/fleet_ramp", 3, || {
            figures::fig16(&h, &cfg).unwrap();
        });
        bench("fig16/shard_sweep", 3, || {
            figures::fig16_shard_sweep(&h, &cfg).unwrap();
        });
    }
}
