//! Bench: Fig. 16 — autoscaling under a camera-fleet ramp, the multi-fog
//! shard sweep (throughput at shard counts {1, 2, 4, 8}), the event-driven
//! vs sequential dispatch comparison (`BENCH_overlap.json`), and the
//! run-scoped streaming vs wave-barrier vs sequential sweep across
//! workload profiles (`BENCH_stream.json`) — both JSON artifacts are
//! uploaded by CI so the perf trajectory is visible per PR.
//!
//! Set `VPAAS_BENCH_SMOKE=1` for the reduced CI configuration: fewer
//! cameras, a shorter dataset, no repeated timing reps — the JSON
//! artifacts are still written.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let smoke = std::env::var("VPAAS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };

    if !smoke {
        let text = figures::fig16(&h, &cfg).unwrap();
        println!("{text}");
        assert!(text.contains("gpus"), "missing provisioning history");
        let sweep = figures::fig16_shard_sweep(&h, &cfg).unwrap();
        println!("{sweep}");
        assert!(sweep.contains("throughput"), "missing shard-sweep throughput");
    }

    // event-driven overlap vs the sequential state machine, as JSON; the
    // smoke configuration shrinks the camera fleet, dataset scale and
    // shard sweep so the per-PR job stays cheap
    let (cameras, scale) = if smoke { (4, 0.1) } else { (6, 0.2) };
    let shard_counts: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8] };
    let (overlap, rows) = figures::fig16_overlap(&h, &cfg, cameras, scale, shard_counts).unwrap();
    println!("{overlap}");
    let entries: Vec<String> = rows
        .iter()
        .map(|(shards, event, seq)| {
            format!(
                "{{\"shards\":{shards},\"event_makespan_s\":{event:.6},\
                 \"sequential_makespan_s\":{seq:.6},\"speedup\":{:.6}}}",
                seq / event.max(1e-12)
            )
        })
        .collect();
    let json = format!(
        "{{\"bench\":\"fig16_overlap\",\"workload\":\"drone x{cameras} cameras\",\"rows\":[{}]}}\n",
        entries.join(",")
    );
    std::fs::write("BENCH_overlap.json", &json).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json: {json}");
    // tiny tolerance: earliest-ready-first can, in principle, delay one
    // long-tailed chunk behind a quicker one on an unlucky seed
    for &(shards, event, seq) in &rows {
        assert!(
            event <= seq * 1.05 + 1e-6,
            "event dispatch slowed the fleet at {shards} shards: {event} vs {seq}"
        );
    }

    // run-scoped streaming vs wave-barrier vs sequential, per workload
    // profile (uniform / bursty / churn), as JSON
    let (stream_text, stream_rows) = figures::fig16_stream(&h, &cfg, cameras, scale).unwrap();
    println!("{stream_text}");
    let entries: Vec<String> = stream_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"workload\":\"{}\",\"chunks\":{},\"streaming_makespan_s\":{:.6},\
                 \"wave_makespan_s\":{:.6},\"sequential_makespan_s\":{:.6},\
                 \"wave_over_streaming\":{:.6}}}",
                r.workload,
                r.chunks,
                r.streaming_s,
                r.wave_s,
                r.sequential_s,
                r.wave_s / r.streaming_s.max(1e-12)
            )
        })
        .collect();
    let json = format!(
        "{{\"bench\":\"fig16_stream\",\"workload\":\"drone x{cameras} cameras, 4 shards\",\
         \"rows\":[{}]}}\n",
        entries.join(",")
    );
    std::fs::write("BENCH_stream.json", &json).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json: {json}");
    // makespan ordering: authoritative gating lives in the tier-1 tests
    // (rust/tests/streaming.rs) at a deliberately chosen configuration;
    // at the reduced smoke scale a miss is reported, not fatal, so the
    // per-PR artifact job cannot flake on an untuned workload size
    for r in &stream_rows {
        let stream_ok = r.streaming_s <= r.wave_s * 1.05 + 1e-6;
        let wave_ok = r.wave_s <= r.sequential_s * 1.05 + 1e-6;
        if smoke {
            if !stream_ok || !wave_ok {
                println!("WARN: makespan ordering violated at smoke scale: {r:?}");
            }
        } else {
            assert!(
                stream_ok,
                "streaming slowed the fleet on {}: {} vs wave {}",
                r.workload, r.streaming_s, r.wave_s
            );
            assert!(
                wave_ok,
                "wave dispatch slower than sequential on {}: {} vs {}",
                r.workload, r.wave_s, r.sequential_s
            );
        }
    }
    // cross-wave overlap must buy real makespan somewhere — at minimum on
    // a bursty profile, where admission piles waves back-to-back. At the
    // tiny smoke scale the waves may genuinely never overlap, so there the
    // miss is reported rather than fatal.
    let strict_win = stream_rows.iter().any(|r| r.streaming_s < r.wave_s);
    if smoke && !strict_win {
        println!("WARN: streaming never beat the wave barrier at smoke scale: {stream_rows:?}");
    } else {
        assert!(strict_win, "streaming never beat the wave barrier: {stream_rows:?}");
    }

    if !smoke {
        bench("fig16/fleet_ramp", 3, || {
            figures::fig16(&h, &cfg).unwrap();
        });
        bench("fig16/shard_sweep", 3, || {
            figures::fig16_shard_sweep(&h, &cfg).unwrap();
        });
    }
}
