//! Bench: Fig. 16 — autoscaling under a camera-fleet ramp.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    let text = figures::fig16(&h, &cfg).unwrap();
    println!("{text}");
    assert!(text.contains("gpus"), "missing provisioning history");
    bench("fig16/fleet_ramp", 3, || {
        figures::fig16(&h, &cfg).unwrap();
    });
}
