//! Bench: Fig. 16 — autoscaling under a camera-fleet ramp, the multi-fog
//! shard sweep (throughput at shard counts {1, 2, 4, 8}), and the
//! event-driven vs sequential dispatch comparison, whose makespans are
//! written to `BENCH_overlap.json` so the perf trajectory is tracked.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness, RunConfig};

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    let text = figures::fig16(&h, &cfg).unwrap();
    println!("{text}");
    assert!(text.contains("gpus"), "missing provisioning history");
    let sweep = figures::fig16_shard_sweep(&h, &cfg).unwrap();
    println!("{sweep}");
    assert!(sweep.contains("throughput"), "missing shard-sweep throughput");

    // event-driven overlap vs the sequential state machine, as JSON
    let (overlap, rows) = figures::fig16_overlap(&h, &cfg).unwrap();
    println!("{overlap}");
    let entries: Vec<String> = rows
        .iter()
        .map(|(shards, event, seq)| {
            format!(
                "{{\"shards\":{shards},\"event_makespan_s\":{event:.6},\
                 \"sequential_makespan_s\":{seq:.6},\"speedup\":{:.6}}}",
                seq / event.max(1e-12)
            )
        })
        .collect();
    let json = format!(
        "{{\"bench\":\"fig16_overlap\",\"workload\":\"drone x6 cameras\",\"rows\":[{}]}}\n",
        entries.join(",")
    );
    std::fs::write("BENCH_overlap.json", &json).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json: {json}");
    // tiny tolerance: earliest-ready-first can, in principle, delay one
    // long-tailed chunk behind a quicker one on an unlucky seed
    for &(shards, event, seq) in &rows {
        assert!(
            event <= seq * 1.05 + 1e-6,
            "event dispatch slowed the fleet at {shards} shards: {event} vs {seq}"
        );
    }

    bench("fig16/fleet_ramp", 3, || {
        figures::fig16(&h, &cfg).unwrap();
    });
    bench("fig16/shard_sweep", 3, || {
        figures::fig16_shard_sweep(&h, &cfg).unwrap();
    });
}
