//! Bench: Fig. 5 — detector output on high vs low quality video.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness};

fn main() {
    let h = Harness::new().expect("artifacts");
    println!("{}", figures::fig5(&h).unwrap());
    println!("{}", figures::quality_operating_points(&h));
    bench("fig5/regenerate", 3, || {
        figures::fig5(&h).unwrap();
    });
}
