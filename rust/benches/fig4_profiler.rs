//! Bench: Fig. 4 — device QC/inference profile + real PJRT model profiling.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::bench;
use vpaas::pipeline::{figures, Harness};
use vpaas::zoo::Profiler;

fn main() {
    let h = Harness::new().expect("artifacts");
    println!("{}", figures::fig4(&h).unwrap());
    let p = h.params.clone();
    let prof = Profiler::new(h.handle());
    bench("fig4/profile_detector_buckets", 5, || {
        prof.profile_model("detector", &[1, 4, 16], |b| vec![vec![b, p.anchors, p.feat_dim]])
            .unwrap();
    });
}
