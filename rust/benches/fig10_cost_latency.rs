//! Bench: Fig. 10 — normalized cloud cost + freshness latency percentiles.
#[path = "bench_support.rs"]
mod bench_support;
use bench_support::{bench, bench_scale};
use vpaas::pipeline::{figures, Harness, RunConfig, SystemKind};
use vpaas::sim::video::datasets;

fn main() {
    let h = Harness::new().expect("artifacts");
    let cfg = RunConfig { golden: false, ..RunConfig::default() };
    let runs = figures::macro_runs(&h, bench_scale(), &cfg).unwrap();
    println!("{}", figures::fig10(&runs));
    for (ds, metrics) in &runs {
        let get = |name: &str| metrics.iter().find(|m| m.system == name).unwrap();
        let mpeg = get("mpeg");
        assert!(
            get("cloudseg").normalized_cost(&mpeg.cost) > 1.8,
            "{ds}: cloudseg must ~double cloud cost"
        );
        assert!(
            get("vpaas").latency.summary().p50 < get("dds").latency.summary().p50,
            "{ds}: vpaas must beat dds latency"
        );
    }
    let ds = datasets::traffic(bench_scale());
    bench("fig10/dds_traffic_end_to_end", 5, || {
        h.run(SystemKind::Dds, &ds, &cfg).unwrap();
    });
}
